"""Logical-axis sharding rules (MaxText-style), divisibility-checked.

Every parameter / activation / cache tensor in the model substrate is
annotated with a tuple of *logical* axis names (one per dimension, or None).
A :class:`ShardingRules` maps logical names to mesh axis names; resolution
checks divisibility and falls back to replication for axes that do not divide
evenly (e.g. qwen2's 28 heads on a model=16 mesh axis), recording the
fallback so EXPERIMENTS.md can report it.

The SHARDING-SEARCH O-task mutates these rules (it is the TPU-specific
platform knob MetaML automates; DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical → mesh mapping.  Entries may map to a tuple of mesh axes
# (composed sharding, e.g. batch over (pod, data)).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,             # sequence replicated by default (train/prefill)
    "cache_seq": "model",    # decode KV caches shard sequence over model axis
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_ffn": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_k": None,
    "kv_lora": None,
    "q_lora": None,
    "frames": None,
    "fsdp": ("pod", "data"),  # ZeRO/FSDP axis for param+opt-state sharding
}


@dataclasses.dataclass
class ShardingRules:
    rules: dict[str, Any]
    mesh: Mesh
    # logical axes that, for this run, shard params over the fsdp axis too
    fsdp_axes: tuple[str, ...] = ()
    fallbacks: list[str] = dataclasses.field(default_factory=list)

    @classmethod
    def default(cls, mesh: Mesh, overrides: dict[str, Any] | None = None,
                fsdp_axes: tuple[str, ...] = ()) -> "ShardingRules":
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        return cls(rules=rules, mesh=mesh, fsdp_axes=fsdp_axes)

    # ------------------------------------------------------------ resolve
    def _mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        target = self.rules.get(logical)
        if target is None:
            return ()
        if isinstance(target, str):
            return (target,)
        return tuple(a for a in target if a is not None)

    def _axis_size(self, mesh_axes: tuple[str, ...]) -> int:
        size = 1
        for a in mesh_axes:
            size *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape)
                         )[a]
        return size

    def spec_for(self, logical_axes: tuple[str | None, ...],
                 dims: tuple[int, ...] | None = None) -> P:
        """PartitionSpec for a tensor with the given logical axes.

        If ``dims`` is provided, divisibility is enforced: a logical axis
        whose dim does not divide by the mesh-axis product is replicated and
        the fallback recorded.  Mesh axes present in the rules but absent
        from the actual mesh (e.g. "pod" on a single-pod mesh) are dropped.
        """
        entries = []
        used: set[str] = set()
        for i, la in enumerate(logical_axes):
            axes = tuple(a for a in self._mesh_axes_for(la)
                         if a in self.mesh.axis_names and a not in used)
            if not axes:
                entries.append(None)
                continue
            if dims is not None:
                size = self._axis_size(axes)
                if dims[i] % size != 0:
                    self.fallbacks.append(
                        f"{la}:dim{dims[i]}%{size}!=0->replicated")
                    entries.append(None)
                    continue
            used.update(axes)
            entries.append(axes[0] if len(axes) == 1 else axes)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, logical_axes: tuple[str | None, ...],
                     dims: tuple[int, ...] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, dims))

    # -------------------------------------------------------- tree helpers
    def tree_specs(self, axes_tree, shape_tree=None):
        """Map a pytree of logical-axis tuples (+optionally shapes) to specs.

        ``axes_tree`` leaves are tuples of logical names; ``shape_tree``
        (same treedef, leaves with ``.shape``) enables divisibility checks.
        """
        if shape_tree is None:
            return jax.tree.map(
                lambda ax: self.spec_for(tuple(ax)), axes_tree,
                is_leaf=lambda x: isinstance(x, tuple))
        return jax.tree.map(
            lambda ax, s: self.spec_for(tuple(ax), tuple(s.shape)),
            axes_tree, shape_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def tree_shardings(self, axes_tree, shape_tree=None):
        specs = self.tree_specs(axes_tree, shape_tree)
        return jax.tree.map(lambda sp: NamedSharding(self.mesh, sp), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def param_specs(self, axes_tree, shape_tree=None, fsdp: bool = False):
        """Param specs; optionally add FSDP sharding on the largest
        replicated dim of each big tensor (ZeRO-3-style weight sharding)."""
        specs = self.tree_specs(axes_tree, shape_tree)
        if not fsdp or shape_tree is None:
            return specs
        fsdp_axes = tuple(a for a in ("pod", "data")
                          if a in self.mesh.axis_names)
        if not fsdp_axes:
            return specs
        fsdp_size = self._axis_size(fsdp_axes)

        def add_fsdp(spec: P, shape):
            dims = tuple(shape.shape)
            if int(np.prod(dims)) < (1 << 20):  # leave small tensors alone
                return spec
            entries = list(spec) + [None] * (len(dims) - len(spec))
            # pick the largest dim not already sharded that divides evenly
            order = sorted(range(len(dims)), key=lambda i: -dims[i])
            for i in order:
                if entries[i] is None and dims[i] % fsdp_size == 0:
                    entries[i] = fsdp_axes if len(fsdp_axes) > 1 \
                        else fsdp_axes[0]
                    break
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)

        return jax.tree.map(add_fsdp, specs, shape_tree,
                            is_leaf=lambda x: isinstance(x, P))


def batch_spec(rules: ShardingRules, ndim: int = 2) -> P:
    """Spec for (batch, seq, ...) data tensors."""
    return rules.spec_for(("batch",) + (None,) * (ndim - 1))
