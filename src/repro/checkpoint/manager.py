"""Checkpointing: atomic, async, retention-managed, elastic-restorable.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json     # treedef paths, shapes, dtypes, logical axes
        arrays.npz        # flattened leaves (host-gathered)
    <dir>/step_000100.COMMITTED   # atomic commit marker

Fault-tolerance contract (runtime/train_loop.py):
- save is atomic: the marker file is written (and fsync'd via rename) only
  after the payload is fully on disk — a crash mid-save never corrupts the
  restore path, which simply picks the newest COMMITTED step.
- async: serialization happens on a background thread off the train loop;
  ``wait()`` joins before the process exits.
- elastic: arrays are saved *unsharded* (host-gathered) with their logical
  axes recorded; ``restore(..., rules=new_rules)`` re-places them onto any
  mesh shape — restarting 512→256 chips re-shards transparently.

At true 1000+-node scale the np.savez payload would be replaced by a
per-shard OCDBT/tensorstore writer; the commit protocol, retention and
elastic re-placement logic here are the parts that carry over.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    flat = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            flat.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            flat.update(_flatten(v, f"{prefix}/{i}"))
    else:
        flat[prefix] = tree
    return flat


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: dict | None = None):
        """Snapshot to host memory now; write to disk (a)synchronously."""
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {"step": step,
                "extra": extra or {},
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in host.items()},
                "time": time.time()}

        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict):
        try:
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, f".tmp_{name}")
            final = os.path.join(self.dir, name)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            # atomic commit marker
            marker = os.path.join(self.dir, f"{name}.COMMITTED")
            with open(marker + ".tmp", "w") as f:
                f.write(str(meta["time"]))
            os.rename(marker + ".tmp", marker)
            self._gc()
        except Exception as e:  # noqa: BLE001
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            name = f"step_{s:08d}"
            marker = os.path.join(self.dir, f"{name}.COMMITTED")
            if os.path.exists(marker):
                os.remove(marker)
            path = os.path.join(self.dir, name)
            if os.path.exists(path):
                shutil.rmtree(path)

    # ------------------------------------------------------------ restore
    def committed_steps(self) -> list[int]:
        steps = []
        for f in os.listdir(self.dir):
            if f.endswith(".COMMITTED"):
                steps.append(int(f[len("step_"):-len(".COMMITTED")]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Load a checkpoint.  ``shardings``: optional pytree-flat dict
        {path: jax.sharding.Sharding} or a full pytree matching the state —
        enables elastic restore onto a different mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        name = f"step_{step:08d}"
        with open(os.path.join(self.dir, name, "manifest.json")) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(self.dir, name, "arrays.npz"))
        flat_shard = _flatten(shardings) if shardings is not None and \
            not isinstance(shardings, dict) else shardings
        flat = {}
        for k in npz.files:
            arr = npz[k]
            if flat_shard is not None and k in flat_shard:
                flat[k] = jax.device_put(arr, flat_shard[k])
            else:
                flat[k] = jnp.asarray(arr)
        return _unflatten(flat), meta
