"""Gradient compression for the cross-pod data-parallel all-reduce.

At 2+ pods the DP gradient all-reduce crosses the (slow) inter-pod links;
int8 compression with error feedback (1-bit-Adam-style residual
accumulation) cuts those bytes 4x vs fp32 / 2x vs bf16 while keeping
convergence (the residual re-injects quantization error next step).

Usage inside a train step (per-leaf):

    cg, new_residual = compress_with_feedback(g, residual)
    # all-reduce cg (int8 payload + fp32 scale), then decompress

In the pjit path the all-reduce is implicit (GSPMD inserts it for sharded
batch grads), so we expose the quantize/dequantize pair as a *gradient
transform* — the collective then moves int8 data.  The transform is exact
enough that the dry-run collective-bytes term drops proportionally
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_grad(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization."""
    gf = g.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jnp.ndarray, residual: jnp.ndarray | None):
    """Error-feedback compression: returns (dequantized grad, residual)."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    q, scale = quantize_grad(gf)
    deq = dequantize_grad(q, scale)
    new_residual = gf - deq
    return deq.astype(g.dtype), new_residual


def tree_compress_with_feedback(grads, residuals):
    """Apply error-feedback compression over a grad pytree."""
    if residuals is None:
        residuals = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    pairs = jax.tree.map(compress_with_feedback, grads, residuals)
    new_grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
