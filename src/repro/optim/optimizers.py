"""Optimizers in pure JAX (no optax in this environment — built from
scratch per the framework scope): SGD, Adam, AdamW with fp32 accumulators,
global-norm clipping, LR schedules.

API mirrors the familiar (init, update) pair:

    opt = adamw(lr=schedule, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state dtype is fp32 regardless of param dtype (mixed-precision
training: bf16 params + fp32 moments).  ZeRO-1 sharding of the moments is
applied by the caller through sharding rules (parallel/sharding.py) — the
moment pytrees mirror params, so param logical axes + the fsdp rule apply
directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup_steps: int,
                    total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        prog = jnp.clip((step - warmup_steps)
                        / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str = "optimizer"


def sgd(lr: float | Schedule, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mom)
            return updates, {"step": step, "mom": mom}
        updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32),
                               grads)
        return updates, {"step": step}

    return Optimizer(init, update, "sgd")


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mhat = m_new / c1
            vhat = v_new / c2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p is not None and p.ndim >= 2:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u, m_new, v_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params) if params is not None \
            else [None] * len(flat_g)
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        m_new = treedef.unflatten([o[1] for o in out])
        v_new = treedef.unflatten([o[2] for o in out])
        return updates, {"step": step, "m": m_new, "v": v_new}

    return Optimizer(init, update, "adamw")


def adam(lr: float | Schedule, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def opt_state_axes(opt_name: str, param_axes):
    """Logical axes for the optimizer state (moments mirror params)."""
    if opt_name == "sgd":
        return {"step": ()}
    return {"step": (), "m": param_axes, "v": param_axes}
