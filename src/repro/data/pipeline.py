"""Deterministic, shardable data pipeline.

Design (scaled-down but structured like a production loader):
- A ``TokenSource`` yields fixed-shape (B, S) token/label batches from a
  flat token stream, deterministically indexed by ``step`` — so a restart
  from checkpoint step k reproduces the exact same batch k (critical for
  fault-tolerant training: data state is just the step counter).
- ``ShardedBatcher`` places host batches onto the mesh with the batch
  sharding from the rules (jax.make_array_from_process_local_data in a real
  multi-host job; single-process here places global arrays directly).
- Background prefetch (one batch ahead) via a tiny double-buffer.
"""

from __future__ import annotations

import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_tokens
from repro.parallel.sharding import ShardingRules


class TokenSource:
    """Deterministic step→batch mapping over a synthetic token stream."""

    def __init__(self, vocab: int, batch: int, seq_len: int,
                 n_tokens: int = 1 << 20, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.stream = lm_tokens(n_tokens, vocab, seed)
        self.n_windows = (len(self.stream) - 1) // seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(step)  # deterministic in step
        idx = rng.integers(0, self.n_windows, self.batch)
        starts = idx * self.seq_len
        toks = np.stack([self.stream[s:s + self.seq_len] for s in starts])
        labels = np.stack([self.stream[s + 1:s + 1 + self.seq_len]
                           for s in starts])
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ShardedBatcher:
    """Places host batches on the mesh with batch sharding + prefetch."""

    def __init__(self, source: TokenSource, rules: ShardingRules | None,
                 prefetch: bool = True):
        self.source = source
        self.rules = rules
        self.prefetch = prefetch
        self._next: dict | None = None
        self._thread: threading.Thread | None = None

    def _place(self, batch: dict[str, np.ndarray]):
        if self.rules is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            sh = self.rules.sharding_for(
                ("batch",) + (None,) * (v.ndim - 1), v.shape)
            out[k] = jax.device_put(jnp.asarray(v), sh)
        return out

    def get(self, step: int):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._next is not None and self._next[0] == step:
            batch = self._next[1]
        else:
            batch = self._place(self.source.batch_at(step))
        self._next = None
        if self.prefetch:
            def work(s):
                self._next = (s, self._place(self.source.batch_at(s)))
            self._thread = threading.Thread(target=work, args=(step + 1,),
                                            daemon=True)
            self._thread.start()
        return batch
