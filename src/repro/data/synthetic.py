"""Synthetic datasets shaped like the paper's benchmarks + LM token streams.

The container has no Jet-HLF / MNIST / SVHN files (DESIGN.md §7), so each
generator produces a *learnable* synthetic task with the original input
shape and class count — the O-task experiments then measure real accuracy
deltas under pruning/scaling/quantization, which is what the paper's claims
are about.

- jet: 16-feature 5-class Gaussian-mixture with class-dependent covariance
  (mimics the HLS4ML jet-substructure tagging problem).
- mnist_like: 28x28x1 images — class-dependent oriented bar patterns+noise.
- svhn_like: 32x32x3 images — class-dependent colour/texture statistics.
- lm_tokens: Zipf-distributed token stream with a Markov flavour so a
  language model has something to learn.
"""

from __future__ import annotations

import numpy as np


def jet_dataset(n: int = 4096, seed: int = 0, n_features: int = 16,
                n_classes: int = 5):
    rng = np.random.default_rng(seed)
    means = rng.normal(0, 1.5, (n_classes, n_features))
    scales = rng.uniform(0.5, 1.5, (n_classes, n_features))
    y = rng.integers(0, n_classes, n)
    x = means[y] + rng.normal(0, 1.0, (n, n_features)) * scales[y]
    # nonlinear structure so depth matters
    x[:, ::2] += 0.3 * np.sin(x[:, 1::2])
    return x.astype(np.float32), y.astype(np.int32)


def _pattern_images(n, seed, size, channels, n_classes):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n)
    xs = np.zeros((n, size, size, channels), np.float32)
    yy, xx = np.mgrid[0:size, 0:size] / size
    for c in range(n_classes):
        idx = np.where(y == c)[0]
        angle = np.pi * c / n_classes
        freq = 2 + c % 4
        base = np.sin(2 * np.pi * freq
                      * (np.cos(angle) * xx + np.sin(angle) * yy))
        for ch in range(channels):
            phase = ch * 0.7 + c * 0.3
            xs[idx, :, :, ch] = base * np.cos(phase) + 0.2 * c / n_classes
    xs += rng.normal(0, 0.35, xs.shape).astype(np.float32)
    return xs, y.astype(np.int32)


def mnist_like(n: int = 2048, seed: int = 0):
    return _pattern_images(n, seed, 28, 1, 10)


def svhn_like(n: int = 2048, seed: int = 0):
    return _pattern_images(n, seed, 32, 3, 10)


def lm_tokens(n_tokens: int, vocab: int, seed: int = 0,
              zipf_a: float = 1.2) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = rng.zipf(zipf_a, n_tokens).astype(np.int64)
    toks = base % vocab
    # inject bigram structure: token2i+1 depends on token2i
    n_odd = len(toks[1::2])
    toks[1::2] = (toks[0::2][:n_odd] * 31 + 7) % vocab
    return toks.astype(np.int32)


DATASETS = {
    "jet": jet_dataset,
    "mnist_like": mnist_like,
    "svhn_like": svhn_like,
}


def train_test_split(x, y, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_test = int(len(x) * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return (x[tr], y[tr]), (x[te], y[te])
