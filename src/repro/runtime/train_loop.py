"""Distributed train/serve step builders + the fault-tolerant training loop.

``make_train_step``/``make_decode_step`` produce jit-compiled functions with
explicit in/out shardings derived from logical-axis rules — these are the
exact functions the multi-pod dry-run lowers (launch/dryrun.py), so what we
roofline is what we run.

The training loop implements the large-scale runnability contract:
- checkpoint/restart (atomic async checkpoints; restore-on-failure),
- failure injection + recovery (simulating node loss → restart from the
  last committed step; data pipeline is deterministic in the step index so
  the restarted run consumes identical batches),
- straggler mitigation (per-step deadline against a running median; slow
  steps are logged and counted — on real fleets this triggers hot-spare
  swap; here the policy + accounting are exercised),
- optional int8 gradient compression with error feedback for the cross-pod
  all-reduce, and microbatched gradient accumulation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.models.api import LMModel
from repro.optim import compression as C
from repro.optim.optimizers import (Optimizer, adamw, apply_updates,
                                    clip_by_global_norm, cosine_schedule,
                                    opt_state_axes)
from repro.parallel.sharding import ShardingRules


# ------------------------------------------------------------- shardings
def state_shardings(model: LMModel, rules: ShardingRules,
                    opt_name: str = "adamw", fsdp: bool = False,
                    zero1: bool = False):
    """Shardings for {params, opt}.

    - ``fsdp``: params AND moments sharded over the dp axes (ZeRO-3-style).
    - ``zero1``: moments only — params stay TP-sharded/replicated, the
      fp32 Adam m/v shard over (pod, data) on top (ZeRO-1).
    """
    p_shapes = model.abstract_params()
    p_axes = model.param_axes()
    p_specs = rules.param_specs(p_axes, p_shapes, fsdp=fsdp)
    o_specs = {"step": P()}
    if opt_name != "sgd":
        o_specs = {"step": P(),
                   "m": rules.param_specs(p_axes, p_shapes,
                                          fsdp=fsdp or zero1 or
                                          bool(rules.fsdp_axes)),
                   "v": rules.param_specs(p_axes, p_shapes,
                                          fsdp=fsdp or zero1 or
                                          bool(rules.fsdp_axes))}
    to_shard = lambda spec: NamedSharding(rules.mesh, spec)  # noqa: E731
    is_spec = lambda x: isinstance(x, P)  # noqa: E731
    return {
        "params": jax.tree.map(to_shard, p_specs, is_leaf=is_spec),
        "opt": jax.tree.map(to_shard, o_specs, is_leaf=is_spec),
    }


def batch_shardings(model: LMModel, rules: ShardingRules, specs: dict):
    out = {}
    for k, v in specs.items():
        out[k] = rules.sharding_for(("batch",) + (None,) * (v.ndim - 1),
                                    v.shape)
    return out


def cache_shardings(model: LMModel, rules: ShardingRules, batch: int,
                    seq_len: int):
    shapes, axes = model.abstract_cache(batch, seq_len)
    specs = rules.tree_specs(axes, shapes)
    return jax.tree.map(lambda sp: NamedSharding(rules.mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------ train step
def make_train_step(model: LMModel, optimizer: Optimizer,
                    *, grad_compression: bool = False,
                    microbatches: int = 1,
                    unroll_microbatches: bool = False,
                    clip_norm: float = 1.0) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt"[, "residuals"]}.
    """
    ctx = model.ctx()

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return loss, metrics

    def compute_grads(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        mb = jax.tree.map(
            lambda t: t.reshape(microbatches, t.shape[0] // microbatches,
                                *t.shape[1:]), batch)

        def scan_body(acc, b):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        if unroll_microbatches:
            # probe path: cost_analysis counts scan bodies once, so the
            # dry-run cost probes unroll the accumulation loop
            acc, losses, ms = zero, [], []
            for i in range(microbatches):
                b = jax.tree.map(lambda t: t[i], mb)
                acc, (l, m) = scan_body(acc, b)
                losses.append(l)
                ms.append(m)
            losses = jnp.stack(losses)
            ms = jax.tree.map(lambda *t: jnp.stack(t), *ms)
            gsum = acc
        else:
            gsum, (losses, ms) = jax.lax.scan(scan_body, zero, mb)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        metrics = jax.tree.map(lambda m: jnp.mean(m), ms)
        return jnp.mean(losses), metrics, grads

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)
        if grad_compression:
            grads, residuals = C.tree_compress_with_feedback(
                grads, state.get("residuals"))
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, state["opt"], params)
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state}
        if grad_compression:
            new_state["residuals"] = residuals
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_decode_step(model: LMModel) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_step


def make_prefill_step(model: LMModel, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        cache, _ = model.init_cache(batch["tokens"].shape[0], cache_len)
        return model.prefill(params, batch, cache=cache)
    return prefill_step


def init_train_state(model: LMModel, optimizer: Optimizer, key,
                     grad_compression: bool = False):
    params = model.init(key)
    state = {"params": params, "opt": optimizer.init(params)}
    if grad_compression:
        state["residuals"] = C.init_residuals(params)
    return state


# ------------------------------------------------------- failure handling
class FailureInjector:
    """Deterministically raises at configured steps (simulated node loss)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: int = 0
    losses: list = dataclasses.field(default_factory=list)
    final_loss: float = float("nan")


def train_loop(model: LMModel, *, steps: int, batcher,
               ckpt: CheckpointManager, optimizer: Optimizer | None = None,
               ckpt_every: int = 10, key=None,
               injector: FailureInjector | None = None,
               straggler_factor: float = 3.0,
               grad_compression: bool = False,
               log: Callable[[str], None] = lambda s: None) -> LoopReport:
    """Fault-tolerant loop: restores from the newest committed checkpoint,
    checkpoints every ``ckpt_every``, and on (injected) failure restarts
    from the last checkpoint — the deterministic data pipeline replays the
    same batches."""
    optimizer = optimizer or adamw(cosine_schedule(3e-4, 10, steps))
    key = key if key is not None else jax.random.PRNGKey(0)
    train_step = jax.jit(make_train_step(
        model, optimizer, grad_compression=grad_compression))

    def fresh_state():
        return init_train_state(model, optimizer, key,
                                grad_compression=grad_compression)

    def load_or_init():
        state, meta = ckpt.restore()
        if state is None:
            return fresh_state(), 0
        return state, meta["step"] + 1

    report = LoopReport()
    state, start = load_or_init()
    if start == 0:
        ckpt.save(-1, state)  # step "-1" = init snapshot
        ckpt.wait()
        start = 0
    step = start
    durations: list[float] = []
    while step < steps:
        try:
            if injector is not None:
                injector.check(step)
            batch = batcher.get(step)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if len(durations) >= 5:
                med = sorted(durations)[len(durations) // 2]
                if dt > straggler_factor * med:
                    report.straggler_events += 1
                    log(f"straggler: step {step} took {dt:.3f}s "
                        f"(median {med:.3f}s)")
            durations.append(dt)
            report.losses.append(loss)
            report.steps_run += 1
            if (step + 1) % ckpt_every == 0 or step + 1 == steps:
                ckpt.save(step, state)
            step += 1
        except RuntimeError as e:
            log(f"failure at step {step}: {e}; restarting from checkpoint")
            report.restarts += 1
            ckpt.wait()
            state, step = load_or_init()
    ckpt.wait()
    report.final_loss = report.losses[-1] if report.losses else float("nan")
    return report
