"""Prebuilt design flows (paper Fig. 2): single-O-task strategies and the
combined cross-stage strategies, in any order — the point of the paper is
that these are a few lines to assemble and re-order.

    flow = pruning_strategy("jet_dnn")            # Fig. 2(a)
    flow = combined_strategy("jet_dnn", "SPQ")    # Fig. 2(b)
    flow = combined_strategy("jet_dnn", "PSQ")    # Fig. 2(c) variant
    meta = flow.execute()

The LM dry-run/roofline flow expresses deliverable (e)/(g) as a MetaML
flow: ModelGen → [O-tasks] → Lower → Compile → Roofline.
"""

from __future__ import annotations

from typing import Any

from repro.core.flow import DesignFlow
from repro.core.metamodel import MetaModel
from repro.tasks.lower import Compile, Lower, Roofline
from repro.tasks.model_gen import ModelGen
from repro.tasks.pruning import Pruning
from repro.tasks.quantization import Quantization
from repro.tasks.scaling import Scaling
from repro.tasks.serve import Serve
from repro.tasks.sharding_search import ShardingSearch
from repro.tasks.tune import Tune

O_TASKS = {"P": Pruning, "S": Scaling, "Q": Quantization,
           "H": ShardingSearch, "T": Tune, "V": Serve}


def pruning_strategy(model: str = "jet_dnn", **params) -> DesignFlow:
    """Paper Fig. 2(a): MODEL-GEN → PRUNING."""
    flow = DesignFlow(f"pruning({model})")
    flow.chain(ModelGen(model=model), Pruning(**params))
    return flow


def scaling_strategy(model: str = "jet_dnn", **params) -> DesignFlow:
    flow = DesignFlow(f"scaling({model})")
    flow.chain(ModelGen(model=model), Scaling(**params))
    return flow


def quantization_strategy(model: str = "jet_dnn", **params) -> DesignFlow:
    flow = DesignFlow(f"quantization({model})")
    flow.chain(ModelGen(model=model), Quantization(**params))
    return flow


def tune_strategy(model: str = "jet_dnn", **params) -> DesignFlow:
    """MODEL-GEN → TUNE: autotune the Pallas tile configs for the shapes
    this model executes (kernels/autotune.py)."""
    flow = DesignFlow(f"tune({model})")
    flow.chain(ModelGen(model=model), Tune(**params))
    return flow


def serve_strategy(model: str = "qwen2-7b",
                   model_params: dict | None = None,
                   tune_params: dict | None = None,
                   serve_params: dict | None = None) -> DesignFlow:
    """MODEL-GEN → TUNE → SERVE (``T → V``): tune the Pallas tile
    configs for the shapes this model executes, then search the joint
    serving-plan space on a traffic profile — the deployment readbacks
    (page size, segment cadence) flow from TUNE to SERVE through the
    persisted autotune cache, and the winner ships as a ServingPlan JSON
    artifact."""
    flow = DesignFlow(f"serve({model})")
    flow.chain(ModelGen(model=model, **(model_params or {})),
               Tune(**(tune_params or {})),
               Serve(**(serve_params or {})))
    return flow


def combined_strategy(model: str = "jet_dnn", order: str = "SPQ",
                      task_params: dict[str, dict] | None = None,
                      model_params: dict | None = None) -> DesignFlow:
    """Combined cross-stage strategy with O-tasks in ``order`` — e.g.
    "SPQ" = scaling → pruning → quantization (paper Fig. 2(b)); "PS" =
    pruning → scaling (Fig. 5(b)).  Reordering is a one-char edit — the
    customizability claim of the paper."""
    task_params = task_params or {}
    flow = DesignFlow(f"{'+'.join(order)}({model})")
    tasks: list[Any] = [ModelGen(model=model, **(model_params or {}))]
    for ch in order:
        tasks.append(O_TASKS[ch](**task_params.get(ch, {})))
    flow.chain(*tasks)
    return flow


def dryrun_flow(arch: str, shape: str = "train_4k",
                multi_pod: bool = False, o_tasks: str = "",
                task_params: dict[str, dict] | None = None) -> DesignFlow:
    """Deliverables (e)/(g) as a MetaML flow:
    ModelGen → [O-tasks] → Lower → Compile → Roofline."""
    task_params = task_params or {}
    flow = DesignFlow(f"dryrun({arch}@{shape})")
    tasks: list[Any] = [ModelGen(model=arch, train_en=False)]
    for ch in o_tasks:
        tasks.append(O_TASKS[ch](**task_params.get(ch, {})))
    tasks += [Lower(shape=shape, multi_pod=multi_pod), Compile(),
              Roofline()]
    flow.chain(*tasks)
    return flow


def run(flow: DesignFlow, cfg: dict | None = None) -> MetaModel:
    return flow.execute(MetaModel(cfg))
