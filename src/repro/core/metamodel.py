"""Meta-model: the shared state of a MetaML design flow.

Paper §III: "The meta-model ... serves as a shared space for storing the states
of the design flow. This model consists of three sections: configuration, log,
and model space."

- CFG    : key-value store holding the parameters of all pipe tasks.
- LOG    : runtime execution trace (used for debugging and for the
           EXPERIMENTS.md iteration logs).
- models : the model space — every artifact generated during flow execution,
           at any abstraction level (DNN / lowered StableHLO / compiled TPU
           executable), together with its reports and computed metrics.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Iterator


# Abstraction levels an artifact can live at.  These mirror the paper's
# DNN / HLS C++ / RTL levels, re-targeted to the JAX/TPU stack (DESIGN.md §2).
LEVEL_DNN = "dnn"            # pure-JAX model (params pytree + apply fn)
LEVEL_LOWERED = "lowered"    # jax .lower() artifact (StableHLO)
LEVEL_COMPILED = "compiled"  # .compile() artifact (+ cost/memory analyses)


@dataclasses.dataclass
class ModelArtifact:
    """One entry in the model space.

    ``payload`` is level-dependent:
      - LEVEL_DNN:      a ``repro.models.api.ModelHandle``
      - LEVEL_LOWERED:  ``jax.stages.Lowered``
      - LEVEL_COMPILED: ``jax.stages.Compiled``
    ``metrics`` holds computed numbers (accuracy, roofline terms, resource
    proxies...); ``reports`` holds larger textual reports (HLO excerpts,
    memory analyses) — the analogue of the paper's "supporting files and tool
    reports".
    """

    name: str
    level: str
    payload: Any
    parent: str | None = None
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)
    reports: dict[str, str] = dataclasses.field(default_factory=dict)
    created_at: float = dataclasses.field(default_factory=time.time)

    def summary(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "level": self.level,
            "parent": self.parent,
            "metrics": {k: v for k, v in self.metrics.items()
                        if isinstance(v, (int, float, str, bool))},
        }


class MetaModel:
    """Shared state for a design flow (CFG / LOG / model space)."""

    def __init__(self, cfg: dict[str, Any] | None = None):
        self.cfg: dict[str, Any] = dict(cfg or {})
        self.log: list[dict[str, Any]] = []
        self._models: dict[str, ModelArtifact] = {}
        self._counter = 0

    # ---------------------------------------------------------------- CFG
    def get(self, key: str, default: Any = None) -> Any:
        return self.cfg.get(key, default)

    def set(self, key: str, value: Any) -> None:
        self.cfg[key] = value

    def update(self, values: dict[str, Any]) -> None:
        self.cfg.update(values)

    # ---------------------------------------------------------------- LOG
    def record(self, event: str, **fields: Any) -> None:
        entry = {"t": time.time(), "event": event, **fields}
        self.log.append(entry)

    def trace(self, event_prefix: str = "") -> list[dict[str, Any]]:
        return [e for e in self.log if e["event"].startswith(event_prefix)]

    def dump_log(self, path: str) -> None:
        with open(path, "w") as f:
            for entry in self.log:
                f.write(json.dumps(entry, default=str) + "\n")

    # -------------------------------------------------------- model space
    def fresh_name(self, stem: str) -> str:
        self._counter += 1
        return f"{stem}#{self._counter}"

    def put(self, artifact: ModelArtifact) -> str:
        self._models[artifact.name] = artifact
        self.record("model_space.put", name=artifact.name,
                    level=artifact.level, parent=artifact.parent)
        return artifact.name

    def add_model(self, stem: str, level: str, payload: Any,
                  parent: str | None = None,
                  metrics: dict[str, Any] | None = None,
                  reports: dict[str, str] | None = None) -> str:
        art = ModelArtifact(name=self.fresh_name(stem), level=level,
                            payload=payload, parent=parent,
                            metrics=dict(metrics or {}),
                            reports=dict(reports or {}))
        return self.put(art)

    def model(self, name: str) -> ModelArtifact:
        return self._models[name]

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def models(self, level: str | None = None) -> Iterator[ModelArtifact]:
        for art in self._models.values():
            if level is None or art.level == level:
                yield art

    def latest(self, level: str | None = None,
               pred: Callable[[ModelArtifact], bool] | None = None
               ) -> ModelArtifact | None:
        best = None
        for art in self.models(level):
            if pred is not None and not pred(art):
                continue
            if best is None or art.created_at >= best.created_at:
                best = art
        return best

    def lineage(self, name: str) -> list[str]:
        """Chain of parents from ``name`` back to the root artifact."""
        chain = [name]
        while self._models[chain[-1]].parent is not None:
            chain.append(self._models[chain[-1]].parent)
        return chain

    def space_summary(self) -> list[dict[str, Any]]:
        return [a.summary() for a in self._models.values()]
