"""Search primitives used inside O-tasks.

The paper's auto-pruning (§V-B) is a binary search:

    maximize   pruning_rate
    subject to accuracy_loss(pruning_rate) <= alpha_p

"Starting at 0% pruning rate, the auto-pruning algorithm obtains initial
accuracy at step 1.  It then uses a binary search approach, increasing or
decreasing the pruning rate based on whether the accuracy loss is within a
user-defined tolerance (<= alpha_p).  The algorithm terminates when the rate
difference is below a threshold (beta_p).  The number of steps is determined
by 1 + log2(1/beta_p)."

These helpers are generic so that PRUNING, SCALING, QUANTIZATION,
SHARDING-SEARCH and TUNE all share the same machinery and the same
step-trace format (consumed by benchmarks/bench_pruning.py to reproduce
Fig. 3/4, and by the TUNE task to publish kernel-tuning trials).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class SearchStep:
    step: int
    x: Any
    objective: float
    feasible: bool
    info: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SearchResult:
    best_x: Any
    best_objective: float
    steps: list[SearchStep]

    @property
    def n_steps(self) -> int:
        return len(self.steps)


def binary_search_max(feasible: Callable[[float], tuple[bool, float, dict]],
                      lo: float = 0.0, hi: float = 1.0,
                      beta: float = 0.02) -> SearchResult:
    """Maximize x in [lo, hi] subject to ``feasible(x)``.

    ``feasible(x)`` returns ``(ok, objective, info)``.  Assumes feasibility is
    (approximately) monotone decreasing in x, as with pruning-rate vs accuracy.
    Terminates when the bracket width is below ``beta``; including the
    initial probe at ``lo`` the paper's step count is ``1 + log2(1/beta)``.
    """
    steps: list[SearchStep] = []

    ok0, obj0, info0 = feasible(lo)
    steps.append(SearchStep(1, lo, obj0, ok0, info0))
    best_x, best_obj = (lo, obj0) if ok0 else (None, -math.inf)

    # Probe the upper end first: if even hi is feasible we are done early.
    ok_hi, obj_hi, info_hi = feasible(hi)
    steps.append(SearchStep(2, hi, obj_hi, ok_hi, info_hi))
    if ok_hi:
        return SearchResult(hi, obj_hi, steps)

    lo_f, hi_i = lo, hi  # feasible lower bound, infeasible upper bound
    while hi_i - lo_f > beta:
        mid = 0.5 * (lo_f + hi_i)
        ok, obj, info = feasible(mid)
        steps.append(SearchStep(len(steps) + 1, mid, obj, ok, info))
        if ok:
            lo_f = mid
            if best_x is None or mid > best_x:
                best_x, best_obj = mid, obj
        else:
            hi_i = mid
    if best_x is None:
        best_x, best_obj = lo, obj0
    return SearchResult(best_x, best_obj, steps)


def monotone_shrink_search(candidates: Sequence[Any],
                           feasible: Callable[[Any], tuple[bool, float, dict]],
                           max_trials: int | None = None) -> SearchResult:
    """Walk ``candidates`` (ordered most→least aggressive shrink is NOT
    assumed; they are tried in order) and keep the last feasible one.

    Used by SCALING: candidates are successively smaller scale factors; the
    search stops at the first infeasible candidate (paper: "The search stops
    when the loss exceeds alpha_s").
    """
    steps: list[SearchStep] = []
    best_x, best_obj = None, -math.inf
    for i, x in enumerate(candidates):
        if max_trials is not None and i >= max_trials:
            break
        ok, obj, info = feasible(x)
        steps.append(SearchStep(len(steps) + 1, x, obj, ok, info))
        if not ok:
            break
        best_x, best_obj = x, obj
    return SearchResult(best_x, best_obj, steps)


def exhaustive_search(candidates: Sequence[Any],
                      evaluate: Callable[[Any], tuple[bool, float, dict]]
                      ) -> SearchResult:
    """Evaluate every candidate; keep the feasible one with the highest
    objective (ties: first seen wins).

    Used by the TUNE O-task: the candidate space is already pruned by the
    autotuner's divisibility/VMEM constraints, so the search is a flat sweep
    with ``objective = -latency_us`` — each measured tile config becomes one
    :class:`SearchStep` in the MetaModel history, same as a pruning probe.
    """
    steps: list[SearchStep] = []
    best_x, best_obj = None, -math.inf
    for x in candidates:
        ok, obj, info = evaluate(x)
        steps.append(SearchStep(len(steps) + 1, x, obj, ok, info))
        if ok and obj > best_obj:
            best_x, best_obj = x, obj
    return SearchResult(best_x, best_obj, steps)


def staged_search(candidates: Sequence[Any],
                  stage1: Callable[[Any], tuple[bool, float, dict]],
                  stage2: Callable[[Any], tuple[bool, float, dict]],
                  *, keep: int | None = None, keep_frac: float = 0.5,
                  must_keep: Sequence[int] = ()) -> SearchResult:
    """Two-stage pruned sweep (SERVE O-task; uptune's intermediate-feature
    idiom).

    Every candidate first runs ``stage1`` — a cheap proxy evaluation whose
    info dict carries intermediate features — and only the top ``keep``
    stage-1 survivors (feasible ones, ranked by stage-1 objective) pay for
    the expensive ``stage2`` evaluation.  ``keep`` defaults to
    ``ceil(keep_frac * len(candidates))``; indices in ``must_keep`` are
    promoted to stage 2 unconditionally (the SERVE task pins its
    hand-assembled default plan there so the searched winner is gated
    against it on equal, stage-2 footing).

    The step trace covers both stages (``info["stage"]`` ∈ {1, 2});
    pruned candidates appear only as their stage-1 step.  The winner is
    the feasible stage-2 candidate with the highest stage-2 objective
    (ties: first seen wins).
    """
    steps: list[SearchStep] = []
    scores: list[tuple[int, bool, float]] = []
    for i, x in enumerate(candidates):
        ok, obj, info = stage1(x)
        steps.append(SearchStep(len(steps) + 1, x, obj, ok,
                                {**info, "stage": 1}))
        scores.append((i, ok, obj))
    if keep is None:
        keep = max(1, math.ceil(keep_frac * len(scores)))
    ranked = sorted((s for s in scores if s[1]),
                    key=lambda s: -s[2])
    survivors = [i for i, _, _ in ranked[:keep]]
    for i in must_keep:
        if i not in survivors and 0 <= i < len(scores):
            survivors.append(i)
    survivors.sort()

    best_x, best_obj = None, -math.inf
    for i in survivors:
        x = candidates[i]
        ok, obj, info = stage2(x)
        steps.append(SearchStep(len(steps) + 1, x, obj, ok,
                                {**info, "stage": 2, "candidate": i}))
        if ok and obj > best_obj:
            best_x, best_obj = x, obj
    return SearchResult(best_x, best_obj, steps)


def greedy_lattice_descent(items: Sequence[str],
                           levels: Sequence[Any],
                           accept: Callable[[dict[str, Any]], tuple[bool, float, dict]],
                           start_level: Any,
                           passes: int = 1) -> tuple[dict[str, Any], SearchResult]:
    """Greedy per-item precision descent (QUANTIZATION O-task).

    Every item (layer) starts at ``start_level``.  For each pass, for each
    item, try moving it one step down the ``levels`` lattice (ordered from
    most to least precise); keep the move iff ``accept(assignment)`` holds.
    Mirrors the paper's iterative per-layer mixed-precision loop: "If the
    accuracy loss is within tolerance (< alpha_q), this process is repeated."
    """
    assignment = {it: start_level for it in items}
    order = {lv: i for i, lv in enumerate(levels)}
    steps: list[SearchStep] = []
    best_obj = -math.inf

    for _ in range(passes):
        changed = False
        for it in items:
            cur = assignment[it]
            idx = order[cur]
            if idx + 1 >= len(levels):
                continue
            trial = dict(assignment)
            trial[it] = levels[idx + 1]
            ok, obj, info = accept(trial)
            steps.append(SearchStep(len(steps) + 1,
                                    {it: str(levels[idx + 1])}, obj, ok, info))
            if ok:
                assignment = trial
                best_obj = obj
                changed = True
        if not changed:
            break
    return assignment, SearchResult(dict(assignment), best_obj, steps)
