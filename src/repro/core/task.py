"""Pipe tasks: the basic unit of a MetaML design flow.

Paper §III/§IV: "The pipe task serves as the basic unit of the design flow,
executing specific optimizations or transformations."  Two kinds:

- O-task: self-contained optimization task that enhances a given model based
  on specific objectives and constraints (PRUNING, SCALING, QUANTIZATION,
  and — TPU-specific, DESIGN.md §2 — SHARDING-SEARCH).
- λ-task: functional transformation on the model space (model generation,
  lowering, compilation — the analogues of HLS4ML / Vivado HLS).

Each task declares a *multiplicity* (paper Table I): how many input and output
model connections it handles, e.g. ``KERAS-MODEL-GEN`` is 0-to-1, all O-tasks
are 1-to-1.
"""

from __future__ import annotations

import time
from typing import Any

from repro.core.metamodel import MetaModel

O_TASK = "O"
LAMBDA_TASK = "λ"


class TaskError(RuntimeError):
    pass


class PipeTask:
    """Base class for design-flow tasks.

    Subclasses set ``kind`` (O_TASK / LAMBDA_TASK), ``n_in``/``n_out``
    (multiplicity) and ``defaults`` (parameter defaults, overridable per
    instance and via the meta-model CFG: CFG key ``f"{name}.{param}"`` wins
    over the instance param, which wins over the class default — this is what
    the paper means by the CFG "holding the parameters of all pipe tasks").
    """

    kind: str = LAMBDA_TASK
    n_in: int = 1
    n_out: int = 1
    defaults: dict[str, Any] = {}

    def __init__(self, name: str | None = None, **params: Any):
        self.name = name or type(self).__name__
        unknown = set(params) - set(type(self).defaults)
        if unknown:
            raise TaskError(f"{self.name}: unknown parameters {sorted(unknown)}")
        self.params = dict(params)

    # ------------------------------------------------------------ config
    def param(self, meta: MetaModel, key: str) -> Any:
        cfg_key = f"{self.name}.{key}"
        if cfg_key in meta.cfg:
            return meta.cfg[cfg_key]
        if key in self.params:
            return self.params[key]
        if key in type(self).defaults:
            return type(self).defaults[key]
        raise TaskError(f"{self.name}: missing parameter {key!r}")

    def all_params(self, meta: MetaModel) -> dict[str, Any]:
        return {k: self.param(meta, k) for k in type(self).defaults}

    # --------------------------------------------------------------- run
    def run(self, meta: MetaModel, inputs: list[str]) -> list[str]:
        """Execute the task.  ``inputs``/outputs are model-space names."""
        if len(inputs) != self.n_in:
            raise TaskError(
                f"{self.name}: expected {self.n_in} input model(s), got "
                f"{len(inputs)} (multiplicity {self.n_in}-to-{self.n_out})")
        t0 = time.time()
        meta.record("task.start", task=self.name, kind=self.kind,
                    inputs=list(inputs), params=self.all_params(meta))
        try:
            outputs = self.execute(meta, inputs)
        except Exception as e:  # noqa: BLE001 — re-raise after logging
            meta.record("task.error", task=self.name, error=repr(e))
            raise
        if len(outputs) != self.n_out:
            raise TaskError(
                f"{self.name}: produced {len(outputs)} outputs, declared "
                f"{self.n_out}")
        meta.record("task.done", task=self.name, outputs=list(outputs),
                    seconds=time.time() - t0)
        return outputs

    def execute(self, meta: MetaModel, inputs: list[str]) -> list[str]:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{self.kind}-task {self.name} {self.n_in}-to-{self.n_out}>"


class OTask(PipeTask):
    kind = O_TASK


class LambdaTask(PipeTask):
    kind = LAMBDA_TASK
