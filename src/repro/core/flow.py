"""Design-flow graphs and their executor.

Paper §III: "The architecture of the design-flow is depicted as a cyclic
directed graph where nodes symbolize tasks and edges signify dependencies
between tasks" and "each connection defines a unidirectional flow between a
source and a target task" (Fig. 1).

Execution model: token passing.  Every edge carries a FIFO of model-space
names.  A node *fires* when every incoming edge holds at least one token; it
consumes one token per edge (in edge-creation order) as its inputs, runs the
task against the shared :class:`MetaModel`, and pushes its outputs to every
outgoing edge whose ``condition(meta, outputs)`` evaluates true.  Source
nodes (``n_in == 0``, e.g. MODEL-GEN) fire exactly once at the start.

Cycles are first-class: a back edge with a condition implements the paper's
iterative optimization loops; the executor bounds total firings with
``max_steps`` so an ill-conditioned flow terminates deterministically.

Contract: within one dispatch, a node's outgoing edge conditions are
evaluated in edge-creation order.  Conditions may rely on this — e.g. a
back-edge condition recording a decision in the MetaModel that a
later-created exit-edge condition reads (examples/custom_flow.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.core.metamodel import MetaModel
from repro.core.task import PipeTask, TaskError

Condition = Callable[[MetaModel, list[str]], bool]


@dataclasses.dataclass
class _Edge:
    src: int
    dst: int
    condition: Condition | None
    tokens: list[str] = dataclasses.field(default_factory=list)


class FlowError(RuntimeError):
    pass


class DesignFlow:
    """A cyclic directed graph of pipe tasks."""

    def __init__(self, name: str = "flow"):
        self.name = name
        self.tasks: list[PipeTask] = []
        self.edges: list[_Edge] = []

    # ------------------------------------------------------- construction
    def add(self, task: PipeTask) -> int:
        self.tasks.append(task)
        return len(self.tasks) - 1

    def connect(self, src: int | PipeTask, dst: int | PipeTask,
                condition: Condition | None = None) -> None:
        s = self._node_id(src)
        d = self._node_id(dst)
        self.edges.append(_Edge(s, d, condition))

    def chain(self, *tasks: PipeTask) -> list[int]:
        """Convenience: add tasks and connect them linearly."""
        ids = [self.add(t) for t in tasks]
        for a, b in zip(ids, ids[1:]):
            self.connect(a, b)
        return ids

    def _node_id(self, node: int | PipeTask) -> int:
        if isinstance(node, int):
            if not 0 <= node < len(self.tasks):
                raise FlowError(f"node id {node} out of range")
            return node
        try:
            return self.tasks.index(node)
        except ValueError:
            raise FlowError(f"task {node!r} not in flow") from None

    # ----------------------------------------------------------- checking
    def validate(self) -> None:
        """Static multiplicity check (paper Table I's multiplicity column).

        A task needs at least ``n_in`` incoming edges; MORE are allowed —
        alternative paths / cyclic back-edges feed the same port (the task
        consumes ``n_in`` tokens per firing from whichever edges hold
        them)."""
        for i, task in enumerate(self.tasks):
            n_in = sum(1 for e in self.edges if e.dst == i)
            if task.n_in > 0 and n_in < task.n_in:
                raise FlowError(
                    f"{self.name}: task {task.name} (node {i}) declares "
                    f"{task.n_in} inputs but has {n_in} incoming edges")
            if task.n_in == 0 and n_in != 0:
                raise FlowError(
                    f"{self.name}: source task {task.name} must have no "
                    f"incoming edges, has {n_in}")

    # ---------------------------------------------------------- execution
    def execute(self, meta: MetaModel | None = None,
                max_steps: int = 256) -> MetaModel:
        meta = meta if meta is not None else MetaModel()
        self.validate()
        for e in self.edges:
            e.tokens.clear()
        meta.record("flow.start", flow=self.name,
                    tasks=[t.name for t in self.tasks])

        fired_source = set()
        steps = 0
        while steps < max_steps:
            node = self._ready_node(fired_source)
            if node is None:
                break
            steps += 1
            task = self.tasks[node]
            inputs = self._consume_inputs(node, task)
            if task.n_in == 0:
                fired_source.add(node)
            outputs = task.run(meta, inputs)
            self._dispatch(meta, node, outputs)
        else:
            raise FlowError(
                f"{self.name}: exceeded max_steps={max_steps}; "
                "a cyclic flow is probably missing a terminating condition")

        meta.record("flow.done", flow=self.name, steps=steps)
        return meta

    def _ready_node(self, fired_source: set[int]) -> int | None:
        for i, task in enumerate(self.tasks):
            if task.n_in == 0:
                if i not in fired_source:
                    return i
                continue
            available = sum(len(e.tokens) for e in self.edges
                            if e.dst == i)
            if available >= task.n_in:
                return i
        return None

    def _consume_inputs(self, node: int, task: PipeTask) -> list[str]:
        inputs: list[str] = []
        for e in self.edges:
            if e.dst == node:
                while e.tokens and len(inputs) < task.n_in:
                    inputs.append(e.tokens.pop(0))
        if len(inputs) != task.n_in:
            raise TaskError(
                f"{task.name}: consumed {len(inputs)} tokens, needs "
                f"{task.n_in}")
        return inputs

    def _dispatch(self, meta: MetaModel, node: int,
                  outputs: list[str]) -> None:
        # Conditions run exactly once per edge, in edge-creation order
        # (module-docstring contract) — side-effecting conditions must not
        # be re-evaluated even for n_out > 1 nodes.
        live: list[_Edge] = []
        for e in self.edges:
            if e.src != node:
                continue
            if e.condition is not None and not e.condition(meta, outputs):
                meta.record("flow.edge_skipped", src=self.tasks[e.src].name,
                            dst=self.tasks[e.dst].name)
                continue
            live.append(e)
        # n_out == 1: the single output fans out to every live edge.
        # n_out > 1: outputs are distributed to live edges in order.
        if self.tasks[node].n_out <= 1:
            for e in live:
                for out in outputs:
                    e.tokens.append(out)
        else:
            for idx, e in enumerate(live):
                if idx < len(outputs):
                    e.tokens.append(outputs[idx])

    # ------------------------------------------------------------- export
    def to_dot(self) -> str:
        """Graphviz rendering of the flow (paper Fig. 2-style)."""
        lines = [f'digraph "{self.name}" {{', "  rankdir=LR;"]
        for i, t in enumerate(self.tasks):
            shape = "box" if t.kind == "λ" else "ellipse"
            lines.append(f'  n{i} [label="{t.name}\\n({t.kind})" '
                         f'shape={shape}];')
        for e in self.edges:
            style = ' [style=dashed label="cond"]' if e.condition else ""
            lines.append(f"  n{e.src} -> n{e.dst}{style};")
        lines.append("}")
        return "\n".join(lines)


def run_linear(tasks: Sequence[PipeTask],
               meta: MetaModel | None = None,
               name: str = "linear-flow") -> MetaModel:
    """Build and execute a simple linear pipeline."""
    flow = DesignFlow(name)
    flow.chain(*tasks)
    return flow.execute(meta)
