"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` executes the kernel bodies in Python on CPU (how this
container validates them); on a real TPU the same calls lower to Mosaic.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.sparsity.masks import block_map

__all__ = ["quant_matmul", "flash_attention", "block_sparse_matmul",
           "masked_matmul", "compact_block_index"]


def masked_matmul(x: jnp.ndarray, w: jnp.ndarray, mask,
                  *, block: int = 128, interpret: bool = False):
    """Convenience: derive the live-block index from a full-res mask and run
    the block-sparse kernel.  (The index would be cached with the pruned
    checkpoint in a real deployment.)"""
    wm = (w.astype(jnp.float32) * mask).astype(w.dtype)
    bmap = block_map(np.asarray(mask), block)
    kidx = jnp.asarray(compact_block_index(bmap))
    return block_sparse_matmul(x, wm, kidx, block=block,
                               interpret=interpret)
