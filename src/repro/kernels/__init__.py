# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.quant_matmul import quant_matmul

__all__ = ["block_sparse_matmul", "compact_block_index", "flash_attention",
           "flash_decode", "quant_matmul", "tuned_block_sparse_matmul",
           "tuned_flash_attention", "tuned_flash_decode",
           "tuned_quant_matmul"]


def __getattr__(name):
    # tuned_* dispatchers pull in core.search; import lazily so plain
    # kernel users don't pay for the autotune machinery.
    if name in ("tuned_block_sparse_matmul", "tuned_flash_attention",
                "tuned_flash_decode", "tuned_quant_matmul"):
        from repro.kernels import autotune
        return getattr(autotune, name)
    raise AttributeError(name)
