"""Pallas TPU kernel: batched ragged prefill attention over a block-table
paged KV cache.

The admission-side mirror of kernels/flash_decode_paged.py: a segment
boundary can admit several requests at once, each with a different prompt
length and a different *shared-prefix offset* (pages already resident
from the prefix cache — serving/paged_cache.py::PrefixCache).  Instead of
one batch-1 prefill dispatch per admission, this kernel computes causal
attention for every admission's *suffix* tokens (the tokens after its
shared prefix) in one dispatch, reading K/V — shared prefix and freshly
scattered suffix alike — straight out of the page pool through the block
table.

Grid: ``(slots, kv_heads, q_tiles, blocks)``.  The innermost dimension
walks the request's block table exactly like the paged decode kernel,
reducing pages into the partial-softmax ``(m, l, acc)`` carry held in
VMEM scratch; the block table rides in as a scalar-prefetch operand so
the K/V index maps DMA page ``bt[r, j]`` directly.  Two more
scalar-prefetch operands carry the per-sequence ragged geometry:
``offsets[r]`` (absolute position of the request's first suffix token =
its shared-prefix length) and ``lens[r]`` (valid suffix tokens).  The
kernel derives its causal/validity mask from them with iotas — the same
predicate ``models/layers.py::ragged_prefill_attention_mask`` builds for
the jnp oracle (pinned against each other in tests/test_paged.py), so
the two paths cannot disagree about which (query, slot) pairs interact.
Tiles with no live pair — a q tile past the request's suffix, a page
beyond the causal frontier, an idle batch slot (``lens[r] == 0``) — skip
their MXU work entirely (``pl.when``), which is what makes one padded
dispatch serve a ragged admission batch.

GQA uses the grouped-q fold of the decode kernels, extended to multiple
query positions: q is laid out ``(R, KV, S * g, D)`` so the ``g`` query
heads sharing a kv head occupy adjacent rows of one tile and score
against a single K/V page read.

``block_q`` is the tunable tile (kernels/autotune.py
``flash_prefill_ragged``); the page size is fixed by the pool layout and
arrives through the K/V shapes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import (NEG_INF, online_softmax_finish,
                                        online_softmax_init)

BQ = 32


def _ragged_prefill_kernel(bt_ref, off_ref, len_ref, q_ref, k_ref, v_ref,
                           out_ref, m_ref, l_ref, acc_ref, *, blocks: int,
                           bq: int, ps: int, g: int, scale: float):
    del bt_ref  # consumed by the BlockSpec index maps, not the body
    ri = pl.program_id(0)
    qi = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    off = off_ref[ri]
    ln = len_ref[ri]
    # rows of the (bq * g, ps) score panel: row -> suffix-local q index
    # (g adjacent rows share one query position), col -> slot in page j.
    # Mirrors models/layers.py::ragged_prefill_attention_mask: a slot
    # participates when its logical position <= the query's absolute
    # position (causal over prefix + own suffix) and the query is live.
    qrel = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq * g, ps),
                                              0) // g
    kv_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (bq * g, ps), 1)
    live = (kv_pos <= off + qrel) & (qrel < ln)

    @pl.when(jnp.any(live))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq * g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (ps, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # rows with no live slot in the whole panel keep m == NEG_INF, so
        # exp(s - m) would be exp(0) = 1 and poison them with a false
        # uniform weighting; zero those terms so dead rows finish at l=0
        # (-> zero output).  Live rows are untouched: their masked slots
        # underflow to exactly 0 anyway.
        p = jnp.where(live, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == blocks - 1)
    def _finish():
        online_softmax_finish(out_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret", "block_q"))
def flash_prefill_ragged(q: jnp.ndarray, k_pages: jnp.ndarray,
                         v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                         offsets: jnp.ndarray, lens: jnp.ndarray, *,
                         interpret: bool = False,
                         block_q: int | None = None) -> jnp.ndarray:
    """q: (R,S,H,D) suffix queries; k/v_pages: (P, page_size, KV, D) with
    H % KV == 0; block_tables: (R, max_blocks) int32 (entries past a
    request's pages parked on the serving layer's scratch page);
    offsets/lens: (R,) int32 — absolute position of q[:, 0] (the shared
    prefix length) and valid suffix tokens per request (0 = idle slot).
    Suffix K/V must already be scattered into the pages (the layer does
    this before attending, exactly like the decode path).  Returns
    (R,S,H,D); rows at or past ``lens`` are zero.
    """
    r, s, h, d = q.shape
    n_pages, ps, kvh, _ = k_pages.shape
    rt, blocks = block_tables.shape
    assert h % kvh == 0, (h, kvh)
    assert rt == r, (rt, r)
    assert offsets.shape == (r,) and lens.shape == (r,)
    g = h // kvh
    bq = min(block_q or BQ, s)
    pad = (-s) % bq
    # grouped-q fold with a seq axis: g query heads sharing one kv head
    # sit in adjacent rows, so one tile is (bq * g, d) rows vs one page
    qf = q.reshape(r, s, kvh, g, d).transpose(0, 2, 1, 3, 4)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    s_p = s + pad
    qf = qf.reshape(r, kvh, s_p * g, d)
    grid = (r, kvh, s_p // bq, blocks)

    out = pl.pallas_call(
        functools.partial(_ragged_prefill_kernel, blocks=blocks, bq=bq,
                          ps=ps, g=g, scale=1.0 / math.sqrt(d)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq * g, d),
                             lambda ri, kv, qi, j, bt, off, ln:
                             (ri, kv, qi, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda ri, kv, qi, j, bt, off, ln:
                             (bt[ri, j], 0, kv, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda ri, kv, qi, j, bt, off, ln:
                             (bt[ri, j], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq * g, d),
                                   lambda ri, kv, qi, j, bt, off, ln:
                                   (ri, kv, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq * g, 1), jnp.float32),
                pltpu.VMEM((bq * g, 1), jnp.float32),
                pltpu.VMEM((bq * g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((r, kvh, s_p * g, d), q.dtype),
        interpret=interpret,
    )(block_tables, offsets.astype(jnp.int32), lens.astype(jnp.int32),
      qf, k_pages, v_pages)
    out = out.reshape(r, kvh, s_p, g, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(r, s_p, h, d)[:, :s]
