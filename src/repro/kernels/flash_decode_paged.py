"""Pallas TPU kernel: paged flash-decoding attention over a block-table KV
cache.

The paged mirror of kernels/flash_decode.py: one generated token per
request attends over that request's KV history, but the cache is no longer
one contiguous ``(B, L, KV, D)`` buffer — it is a shared pool of
fixed-size pages ``(P, page_size, KV, D)`` plus a per-request *block
table* mapping logical block ``j`` of request ``r`` to a physical page.
That indirection is what lets the serving engine admit/evict requests
without ever copying or compacting KV state (src/repro/serving/).

Mechanically the kv-split of flash_decode becomes the page: the grid is
``(slots, kv_heads, blocks_per_req)`` and the innermost dimension walks
the request's block table, reducing pages with the partial-softmax
``(m, l, acc)`` carry in VMEM scratch.  The block table rides in as a
*scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``) so the K/V
BlockSpec index maps can dereference it — the DMA for page ``bt[r, j]``
is issued directly from the table, no gather of the pool ever
materializes.

GQA uses the same grouped-q fold as flash_decode: q is reshaped
``(R, H, D) -> (R, KV, g, D)`` so the ``g`` query heads sharing a kv head
score against one K/V page read.

Masking follows the PR-2 contract: the kernel consumes a precomputed
``(R, max_blocks * page_size)`` validity mask built by the caller from
``models/layers.py::paged_kv_positions`` / ``paged_decode_attention_mask``
— the same helpers the jnp oracle uses, so the two paths cannot disagree
about which slots are live.  Ragged per-request lengths are just ragged
masks; blocks past a short request's length skip their MXU work entirely
(``pl.when``), which is what makes one dispatch serve a ragged batch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import (online_softmax_finish,
                                        online_softmax_init,
                                        online_softmax_step)

DEFAULT_PAGE_SIZE = 16


def _paged_decode_kernel(bt_ref, q_ref, k_ref, v_ref, mask_ref, out_ref,
                         m_ref, l_ref, acc_ref, *, blocks: int,
                         scale: float):
    del bt_ref  # consumed by the BlockSpec index maps, not the body
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    live = mask_ref[...] != 0                          # (1, ps)

    @pl.when(jnp.any(live))
    def _step():
        online_softmax_step(q_ref, k_ref, v_ref, live,
                            m_ref, l_ref, acc_ref, scale=scale)

    @pl.when(j == blocks - 1)
    def _finish():
        online_softmax_finish(out_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                       mask: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (R,1,H,D); k/v_pages: (P, page_size, KV, D) with H % KV == 0;
    block_tables: (R, max_blocks) int32 physical page per logical block
    (entries past a request's length must still be valid page indices —
    the serving layer parks them on its reserved scratch page); mask:
    (R, max_blocks * page_size) bool — True where the logical slot
    participates.  Returns (R,1,H,D).  The page size is the kv-split: it
    is fixed by the pool layout, so it is tuned at pool-construction time
    (kernels/autotune.py ``flash_decode_paged``), not per call.
    """
    r, sq, h, d = q.shape
    n_pages, ps, kvh, _ = k_pages.shape
    rt, blocks = block_tables.shape
    assert sq == 1, f"flash_decode_paged is single-token (got sq={sq})"
    assert h % kvh == 0, (h, kvh)
    assert rt == r, (rt, r)
    assert mask.shape == (r, blocks * ps), (mask.shape, r, blocks, ps)
    g = h // kvh
    qf = q[:, 0].reshape(r, kvh, g, d)
    mf = mask.astype(jnp.int32)
    grid = (r, kvh, blocks)

    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, blocks=blocks,
                          scale=1.0 / math.sqrt(d)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda ri, kv, j, bt: (ri, kv, 0, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda ri, kv, j, bt: (bt[ri, j], 0, kv, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda ri, kv, j, bt: (bt[ri, j], 0, kv, 0)),
                pl.BlockSpec((1, ps),
                             lambda ri, kv, j, bt: (ri, j)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda ri, kv, j, bt: (ri, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((r, kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, qf, k_pages, v_pages, mf)
    return out.reshape(r, 1, h, d)
