"""Per-shape tile autotuner for the Pallas kernels.

MetaML's claim is "automating the selection and configuration of low-level
optimization techniques"; on the TPU stack the low-level knobs are Pallas
tile sizes.  This module closes that loop: for a concrete (kernel, shape,
dtype, flags) problem it

1. enumerates a *pruned* candidate space — tile sizes drawn from
   :data:`TILE_SIZES`, filtered by divisibility against the problem shape
   and by a VMEM-footprint model against :data:`VMEM_BUDGET` (a candidate
   that would not fit on-chip is never timed);
2. measures every surviving candidate with the benchmarks/common.py
   ``timeit`` harness (interpret mode on CPU, real timing on TPU);
3. memoizes the winner in a persistent on-disk JSON cache keyed by
   ``kernel|problem`` so later calls — including future processes — skip
   straight to the tuned config.

The default (128x128[,512]) config is always part of the candidate space,
so the tuned config is never slower than the fixed default *as measured*.

Cache file format (``REPRO_AUTOTUNE_CACHE`` or ~/.cache/repro/autotune.json)::

    {"version": 1,
     "entries": {
       "quant_matmul|{\"dtype\":\"float32\",\"k\":512,...}": {
         "config": {"block_m": 256, "block_n": 128, "block_k": 512},
         "us": 1234.5,
         "n_trials": 9,
         "backend": "cpu",
         "t": 1700000000.0}}}

The TUNE O-task (tasks/tune.py) drives :func:`tune` and republishes every
trial as a ``SearchStep`` in the MetaModel history; ``tuned_*`` wrappers
give kernels-layer callers transparent tune-on-miss dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import SearchResult, exhaustive_search
from repro.kernels.block_sparse_matmul import block_sparse_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, pick_block_kv
from repro.kernels.flash_decode_paged import (DEFAULT_PAGE_SIZE,
                                              flash_decode_paged)
from repro.kernels.flash_prefill_ragged import BQ as BQ_PREFILL
from repro.kernels.flash_prefill_ragged import flash_prefill_ragged
from repro.kernels.quant_matmul import BK, BM, BN, quant_matmul

TILE_SIZES = (32, 64, 128, 256)
# Conservative per-step budget: half of the ~16 MB VMEM per TPU core,
# leaving headroom for double-buffered pipelining of the HBM->VMEM copies.
VMEM_BUDGET = 8 * 2 ** 20
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1


# --------------------------------------------------------------------- cache
def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


_MEM: dict[str, dict[str, Any]] = {}   # path -> {"entries": {...}} (loaded once)


def _load(path: str) -> dict[str, Any]:
    if path not in _MEM:
        data: dict[str, Any] = {"version": CACHE_VERSION, "entries": {}}
        try:
            with open(path) as f:
                raw = json.load(f)
            if raw.get("version") == CACHE_VERSION:
                data = raw
        except (OSError, ValueError):
            pass
        _MEM[path] = data
    return _MEM[path]


def _store(path: str, key: str, entry: dict[str, Any]) -> None:
    # Merge against a fresh read of the file, not the process snapshot:
    # concurrent writers (pytest-xdist, a flow next to a bench) would
    # otherwise have their entries clobbered by our stale view.  The temp
    # name is per-writer so two simultaneous stores cannot interleave
    # inside one file; last os.replace wins.
    _MEM.pop(path, None)
    data = _load(path)
    data["entries"][key] = entry
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def clear_memory_cache() -> None:
    """Drop the in-process view of every cache file (tests)."""
    _MEM.clear()
    _RESOLVED.clear()


def cache_key(kernel: str, problem: dict[str, Any]) -> str:
    return f"{kernel}|{json.dumps(problem, sort_keys=True)}"


# ------------------------------------------------------------------- results
@dataclasses.dataclass(frozen=True)
class Trial:
    config: dict[str, int]
    us: float
    vmem_bytes: int


@dataclasses.dataclass
class TuneResult:
    kernel: str
    key: str
    config: dict[str, int]
    us: float
    cached: bool
    trials: list[Trial] = dataclasses.field(default_factory=list)
    search: SearchResult | None = None   # None on a cache hit

    @property
    def default_us(self) -> float | None:
        default = KERNELS[self.kernel].default_config
        for t in self.trials:
            if t.config == default:
                return t.us
        return None


# ------------------------------------------------------- kernel descriptors
def _itemsize(dtype: str) -> int:
    return jnp.dtype(dtype).itemsize


def _divides(tile: int, dim: int) -> bool:
    return dim % min(tile, dim) == 0


def _axis(default: int, extra: tuple[int, ...] = ()) -> tuple[int, ...]:
    """Tile sizes for one dim, default first: when small problem dims clamp
    several nominal tiles to the same effective tile, the dedup in the
    candidate generators keeps the first-seen config — default-first makes
    that representative the literal default config, preserving the
    'default is always measured' invariant (and TuneResult.default_us)."""
    sizes = set(TILE_SIZES) | set(extra) | {default}
    return tuple(sorted(sizes, key=lambda t: (t != default, t)))


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One tunable kernel: candidate model + benchmark-input factory."""

    name: str
    default_config: dict[str, int]
    candidates: Callable[[dict[str, Any]], list[tuple[dict[str, int], int]]]
    make_runner: Callable[[dict[str, Any], dict[str, int], bool],
                          Callable[[], Any]]


# flash attention ------------------------------------------------------------
def _fa_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    d = problem["d"]
    bq = min(cfg["block_q"], problem["sq"])
    bkv = min(cfg["block_kv"], problem["skv"])
    item = _itemsize(problem["dtype"])
    blocks = (2 * bq * d + 2 * bkv * d) * item      # q, out, k, v tiles
    scratch = (2 * bq + bq * d) * 4                 # m, l, acc (f32)
    temps = 2 * bq * bkv * 4                        # s and p (f32)
    return blocks + scratch + temps


def _fa_candidates(problem: dict[str, Any]
                   ) -> list[tuple[dict[str, int], int]]:
    out, seen = [], set()
    for bq in _axis(128):
        for bkv in _axis(128):
            cfg = {"block_q": bq, "block_kv": bkv}
            eff = (min(bq, problem["sq"]), min(bkv, problem["skv"]))
            if eff in seen:     # clamped duplicates time identically
                continue
            seen.add(eff)
            out.append((cfg, _fa_vmem(problem, cfg)))
    return out


@functools.lru_cache(maxsize=8)
def _fa_inputs(problem_json: str):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    q = jax.random.normal(
        jax.random.PRNGKey(0),
        (problem["b"], problem["sq"], problem["h"], problem["d"])
    ).astype(dtype)
    kv_shape = (problem["b"], problem["skv"], problem["kv_heads"],
                problem["d"])
    k = jax.random.normal(jax.random.PRNGKey(1), kv_shape).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), kv_shape).astype(dtype)
    return q, k, v


def _fa_runner(problem: dict[str, Any], cfg: dict[str, int],
               interpret: bool) -> Callable[[], Any]:
    # inputs depend only on the problem: build once per search, not per
    # candidate (lru keyed on the canonical problem JSON)
    q, k, v = _fa_inputs(json.dumps(problem, sort_keys=True))
    return lambda: flash_attention(
        q, k, v, causal=problem["causal"], window=problem["window"],
        interpret=interpret, block_q=cfg["block_q"],
        block_kv=cfg["block_kv"])


def flash_attention_problem(q_shape, kv_shape, dtype, *,
                            causal: bool = True,
                            window: int = 0) -> dict[str, Any]:
    b, sq, h, d = (int(x) for x in q_shape)
    _, skv, kvh, _ = (int(x) for x in kv_shape)
    return {"b": b, "sq": sq, "h": h, "d": d, "skv": skv, "kv_heads": kvh,
            "dtype": jnp.dtype(dtype).name, "causal": bool(causal),
            "window": int(window)}


# flash decode ---------------------------------------------------------------
def _fd_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    d = problem["d"]
    g = problem["h"] // problem["kv_heads"]
    bkv = pick_block_kv(cfg["block_kv"], problem["cache_len"])
    item = _itemsize(problem["dtype"])
    blocks = (2 * g * d + 2 * bkv * d) * item       # q, out, k, v tiles
    mask = bkv * 4                                  # int32 validity tile
    scratch = (2 * g + g * d) * 4                   # m, l, acc (f32)
    temps = 2 * g * bkv * 4                         # s and p (f32)
    return blocks + mask + scratch + temps


def _fd_candidates(problem: dict[str, Any]
                   ) -> list[tuple[dict[str, int], int]]:
    # block_kv IS the kv-split: cache_len / block_kv partial-softmax steps.
    # 512 joins the space for long caches where fewer, fatter tiles win.
    out, seen = [], set()
    for bkv in _axis(128, (512,)):
        cfg = {"block_kv": bkv}
        # dedup on the divisor-safe effective tile the kernel will run
        # (clamping and ragged-snap both collapse nominal candidates)
        eff = pick_block_kv(bkv, problem["cache_len"])
        if eff in seen:
            continue
        seen.add(eff)
        out.append((cfg, _fd_vmem(problem, cfg)))
    return out


@functools.lru_cache(maxsize=8)
def _fd_inputs(problem_json: str):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    b, h, d = problem["b"], problem["h"], problem["d"]
    kvh, skv = problem["kv_heads"], problem["cache_len"]
    q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1),
                          (b, skv, kvh, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2),
                          (b, skv, kvh, d)).astype(dtype)
    # a full cache is the steady-state (and worst-case) decode problem
    mask = jnp.ones((skv,), jnp.bool_)
    return q, k, v, mask


def _fd_runner(problem: dict[str, Any], cfg: dict[str, int],
               interpret: bool) -> Callable[[], Any]:
    q, k, v, mask = _fd_inputs(json.dumps(problem, sort_keys=True))
    return lambda: flash_decode(q, k, v, mask, interpret=interpret,
                                block_kv=cfg["block_kv"])


def flash_decode_problem(q_shape, kv_shape, dtype) -> dict[str, Any]:
    b, _, h, d = (int(x) for x in q_shape)
    _, skv, kvh, _ = (int(x) for x in kv_shape)
    return {"b": b, "h": h, "d": d, "kv_heads": kvh, "cache_len": skv,
            "dtype": jnp.dtype(dtype).name}


# paged flash decode ---------------------------------------------------------
def _fpd_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    d = problem["d"]
    g = problem["h"] // problem["kv_heads"]
    # the K/V tile is always a full page — a page larger than max_len is
    # padded, not clamped (unlike the contiguous kernels' tiles)
    ps = cfg["page_size"]
    item = _itemsize(problem["dtype"])
    blocks = (2 * g * d + 2 * ps * d) * item        # q, out, k, v page tiles
    mask = ps * 4                                   # int32 validity tile
    scratch = (2 * g + g * d) * 4                   # m, l, acc (f32)
    temps = 2 * g * ps * 4                          # s and p (f32)
    return blocks + mask + scratch + temps


def _fpd_candidates(problem: dict[str, Any]
                    ) -> list[tuple[dict[str, int], int]]:
    # page_size IS the kv-split of the paged kernel AND the pool's
    # allocation granule: small pages fragment less (~page_size/2 wasted
    # tokens per request), big pages mean fewer grid steps per token.
    # The tuner times the kernel side; the engine reads the winner back
    # at pool-construction time (serving/paged_cache.preferred_page_size).
    # Ascending enumeration + effective-coverage dedup: page sizes whose
    # effective coverage min(ps, max_len) collapses are redundant grids,
    # and keeping the SMALLEST representative keeps pool padding minimal
    # (a covering page larger than max_len only wastes pool bytes).  The
    # default page size is force-included even when it collapses, so the
    # 'default is always measured' invariant (and the distance-sorted cap
    # in enumerate_candidates) holds like every other kernel.
    out, seen = [], set()
    for ps in sorted(set(TILE_SIZES) | {8, 16, DEFAULT_PAGE_SIZE}):
        eff = min(ps, problem["max_len"])
        if eff in seen and ps != DEFAULT_PAGE_SIZE:
            continue
        seen.add(eff)
        cfg = {"page_size": ps}
        out.append((cfg, _fpd_vmem(problem, cfg)))
    return out


@functools.lru_cache(maxsize=16)
def _fpd_inputs(problem_json: str, page_size: int):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    slots, h, d = problem["slots"], problem["h"], problem["d"]
    kvh, max_len = problem["kv_heads"], problem["max_len"]
    blocks = -(-max_len // page_size)
    n_pages = slots * blocks + 1           # + the reserved scratch page
    q = jax.random.normal(jax.random.PRNGKey(0),
                          (slots, 1, h, d)).astype(dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1),
                           (n_pages, page_size, kvh, d)).astype(dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2),
                           (n_pages, page_size, kvh, d)).astype(dtype)
    bt = 1 + jnp.arange(slots * blocks, dtype=jnp.int32).reshape(
        slots, blocks)
    # steady-state (worst-case) decode: every request near capacity
    mask = jnp.broadcast_to(
        jnp.arange(blocks * page_size)[None, :] < max_len,
        (slots, blocks * page_size))
    return q, kp, vp, bt, mask


def _fpd_runner(problem: dict[str, Any], cfg: dict[str, int],
                interpret: bool) -> Callable[[], Any]:
    q, kp, vp, bt, mask = _fpd_inputs(
        json.dumps(problem, sort_keys=True), cfg["page_size"])
    return lambda: flash_decode_paged(q, kp, vp, bt, mask,
                                      interpret=interpret)


def flash_decode_paged_problem(slots: int, h: int, kv_heads: int, d: int,
                               max_len: int, dtype) -> dict[str, Any]:
    return {"slots": int(slots), "h": int(h), "kv_heads": int(kv_heads),
            "d": int(d), "max_len": int(max_len),
            "dtype": jnp.dtype(dtype).name}


# paged decode segment -------------------------------------------------------
# Not a kernel tile but a *scheduler cadence*: the serving engine decodes
# in fixed-length lax.scan segments and wakes the host only at segment
# boundaries (retire/admit/grow/preempt).  Long segments amortize the
# host sync + dispatch overhead per token; short segments react faster
# (admissions wait less, finished slots idle less, and the resource
# manager's growth granule — the pages one segment consumes — shrinks,
# so an oversubscribed pool preempts less speculatively).  The timing
# harness can only see the first half of that trade, so candidates all
# generate the SAME token budget split into different dispatch sizes
# with a host sync between dispatches — exactly the engine's boundary
# pattern — and the winner is the cadence whose overhead amortization
# actually pays on this backend.  The engine reads it back through
# serving/paged_cache.py::preferred_segment_len.
SEGMENT_TOKENS = 32          # fixed token budget every candidate pays


def _pseg_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    # per grid step the resident working set is flash_decode_paged's at
    # the pool's page size; segment_len moves dispatch count, not tiles
    d = problem["d"]
    g = problem["h"] // problem["kv_heads"]
    ps = problem["page_size"]
    item = _itemsize(problem["dtype"])
    blocks = (2 * g * d + 2 * ps * d) * item
    mask = ps * 4
    scratch = (2 * g + g * d) * 4
    temps = 2 * g * ps * 4
    return blocks + mask + scratch + temps


def _pseg_candidates(problem: dict[str, Any]
                     ) -> list[tuple[dict[str, int], int]]:
    out = []
    for sl in (2, 4, 8, 16, SEGMENT_TOKENS):
        out.append(({"segment_len": sl}, _pseg_vmem(problem, {})))
    return out


@functools.lru_cache(maxsize=16)
def _pseg_fn(problem_json: str, seg_len: int, interpret: bool):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    slots, h, d = problem["slots"], problem["h"], problem["d"]
    kvh, max_len, ps = (problem["kv_heads"], problem["max_len"],
                        problem["page_size"])
    blocks = -(-max_len // ps)
    n_pages = slots * blocks + 1           # + the reserved scratch page
    q = jax.random.normal(jax.random.PRNGKey(0),
                          (slots, 1, h, d)).astype(dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1),
                           (n_pages, ps, kvh, d)).astype(dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2),
                           (n_pages, ps, kvh, d)).astype(dtype)
    bt = 1 + jnp.arange(slots * blocks, dtype=jnp.int32).reshape(
        slots, blocks)
    n = blocks * ps
    # start half-full: the scan advances seq_lens like a real segment
    sl0 = jnp.full((slots,), max(1, max_len // 2), jnp.int32)

    def segment(sl):
        def step(carry, _):
            cur = carry
            mask = jnp.arange(n)[None, :] < jnp.minimum(
                cur + 1, max_len)[:, None]
            out = flash_decode_paged(q, kp, vp, bt, mask,
                                     interpret=interpret)
            return jnp.minimum(cur + 1, max_len - 1), out[:, 0, 0, 0]
        sl, outs = jax.lax.scan(step, sl, None, length=seg_len)
        return sl, outs

    return jax.jit(segment), sl0


def _pseg_runner(problem: dict[str, Any], cfg: dict[str, int],
                 interpret: bool) -> Callable[[], Any]:
    seg_len = min(cfg["segment_len"], SEGMENT_TOKENS)
    fn, sl0 = _pseg_fn(json.dumps(problem, sort_keys=True), seg_len,
                       interpret)
    reps = SEGMENT_TOKENS // seg_len

    def run():
        sl, outs = sl0, None
        for _ in range(reps):
            sl, outs = fn(sl)
            # the engine pulls control state back at every boundary;
            # blocking here reproduces that sync cost per dispatch
            jax.block_until_ready(outs)
        return outs

    return run


def paged_segment_problem(slots: int, h: int, kv_heads: int, d: int,
                          max_len: int, page_size: int,
                          dtype) -> dict[str, Any]:
    return {"slots": int(slots), "h": int(h), "kv_heads": int(kv_heads),
            "d": int(d), "max_len": int(max_len),
            "page_size": int(page_size),
            "dtype": jnp.dtype(dtype).name}


# ragged paged prefill -------------------------------------------------------
def _fpr_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    d = problem["d"]
    g = problem["h"] // problem["kv_heads"]
    # the wrapper clamps block_q to the suffix length; the K/V tile is
    # always one full page (fixed by the pool layout)
    bq = min(cfg["block_q"], problem["s"]) * g
    ps = problem["page_size"]
    item = _itemsize(problem["dtype"])
    blocks = (2 * bq * d + 2 * ps * d) * item       # q, out, k, v tiles
    scratch = (2 * bq + bq * d) * 4                 # m, l, acc (f32)
    temps = 2 * bq * ps * 4                         # s and p (f32)
    return blocks + scratch + temps


def _fpr_candidates(problem: dict[str, Any]
                    ) -> list[tuple[dict[str, int], int]]:
    # block_q tiles the suffix-query axis; the kv axis is walked page by
    # page (the pool's page size — the prefix-match granule — is part of
    # the problem, tuned through flash_decode_paged, not re-tuned here).
    out, seen = [], set()
    for bq in _axis(BQ_PREFILL, (8, 16)):
        cfg = {"block_q": bq}
        eff = min(bq, problem["s"])     # wrapper clamps: duplicates collapse
        if eff in seen:
            continue
        seen.add(eff)
        out.append((cfg, _fpr_vmem(problem, cfg)))
    return out


@functools.lru_cache(maxsize=16)
def _fpr_inputs(problem_json: str):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    slots, s, h, d = (problem["slots"], problem["s"], problem["h"],
                      problem["d"])
    kvh, max_len, ps = (problem["kv_heads"], problem["max_len"],
                        problem["page_size"])
    blocks = -(-max_len // ps)
    n_pages = slots * blocks + 1           # + the reserved scratch page
    q = jax.random.normal(jax.random.PRNGKey(0),
                          (slots, s, h, d)).astype(dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1),
                           (n_pages, ps, kvh, d)).astype(dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2),
                           (n_pages, ps, kvh, d)).astype(dtype)
    bt = 1 + jnp.arange(slots * blocks, dtype=jnp.int32).reshape(
        slots, blocks)
    # worst case: every suffix sits at the end of a near-full prefix, so
    # each query attends the whole history
    off = jnp.full((slots,), max(0, max_len - s), jnp.int32)
    lens = jnp.full((slots,), s, jnp.int32)
    return q, kp, vp, bt, off, lens


def _fpr_runner(problem: dict[str, Any], cfg: dict[str, int],
                interpret: bool) -> Callable[[], Any]:
    q, kp, vp, bt, off, lens = _fpr_inputs(
        json.dumps(problem, sort_keys=True))
    return lambda: flash_prefill_ragged(q, kp, vp, bt, off, lens,
                                        interpret=interpret,
                                        block_q=cfg["block_q"])


def flash_prefill_ragged_problem(slots: int, s: int, h: int, kv_heads: int,
                                 d: int, max_len: int, page_size: int,
                                 dtype) -> dict[str, Any]:
    """``s`` is the padded suffix bucket, ``max_len`` the logical slots
    per request (block-table width x page size)."""
    return {"slots": int(slots), "s": int(s), "h": int(h),
            "kv_heads": int(kv_heads), "d": int(d),
            "max_len": int(max_len), "page_size": int(page_size),
            "dtype": jnp.dtype(dtype).name}


# quant matmul ---------------------------------------------------------------
def _qmm_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    bm = min(cfg["block_m"], problem["m"])
    bn = min(cfg["block_n"], problem["n"])
    bk = min(cfg["block_k"], problem["k"])
    blocks = bm * bk + bk * bn          # int8 tiles
    scales = (bm + bn) * 4
    acc = bm * bn * 4                   # int32 accumulator
    out = bm * bn * _itemsize(problem["out_dtype"])
    temps = bm * bn * 4                 # dequant f32 temporary
    return blocks + scales + acc + out + temps


def _qmm_candidates(problem: dict[str, Any]
                    ) -> list[tuple[dict[str, int], int]]:
    m, n, k = problem["m"], problem["n"], problem["k"]
    out, seen = [], set()
    for bm in _axis(BM):
        for bn in _axis(BN):
            for bk in _axis(BK):
                if not (_divides(bm, m) and _divides(bn, n)
                        and _divides(bk, k)):
                    continue
                eff = (min(bm, m), min(bn, n), min(bk, k))
                if eff in seen:
                    continue
                seen.add(eff)
                cfg = {"block_m": bm, "block_n": bn, "block_k": bk}
                out.append((cfg, _qmm_vmem(problem, cfg)))
    return out


@functools.lru_cache(maxsize=8)
def _mm_inputs(problem_json: str):
    problem = json.loads(problem_json)
    dtype = jnp.dtype(problem["dtype"])
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (problem["m"], problem["k"])).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (problem["k"], problem["n"])).astype(dtype)
    return x, w


def _qmm_runner(problem: dict[str, Any], cfg: dict[str, int],
                interpret: bool) -> Callable[[], Any]:
    x, w = _mm_inputs(json.dumps(problem, sort_keys=True))
    return lambda: quant_matmul(
        x, w, interpret=interpret, block_m=cfg["block_m"],
        block_n=cfg["block_n"], block_k=cfg["block_k"])


def quant_matmul_problem(x_shape, w_shape, dtype, *,
                         out_dtype=jnp.float32) -> dict[str, Any]:
    m, k = (int(v) for v in x_shape)
    _, n = (int(v) for v in w_shape)
    return {"m": m, "k": k, "n": n, "dtype": jnp.dtype(dtype).name,
            "out_dtype": jnp.dtype(out_dtype).name}


# block-sparse matmul --------------------------------------------------------
def _bsmm_vmem(problem: dict[str, Any], cfg: dict[str, int]) -> int:
    block = problem["block"]
    bm = min(cfg["block_m"], problem["m"])
    item = _itemsize(problem["dtype"])
    blocks = (bm * block + block * block) * item    # x, w tiles
    acc_out = 2 * bm * block * 4                    # acc scratch + out tile
    return blocks + acc_out


def _bsmm_candidates(problem: dict[str, Any]
                     ) -> list[tuple[dict[str, int], int]]:
    m = problem["m"]
    out, seen = [], set()
    for bm in _axis(128):
        if not _divides(bm, m):
            continue
        eff = min(bm, m)
        if eff in seen:
            continue
        seen.add(eff)
        cfg = {"block_m": bm}
        out.append((cfg, _bsmm_vmem(problem, cfg)))
    return out


def _bsmm_runner(problem: dict[str, Any], cfg: dict[str, int],
                 interpret: bool) -> Callable[[], Any]:
    block = problem["block"]
    x, w = _mm_inputs(json.dumps(problem, sort_keys=True))
    nb = problem["n"] // block
    live = min(problem["max_live"], problem["k"] // block)
    kidx = jnp.asarray(np.tile(np.arange(live, dtype=np.int32), (nb, 1)))
    return lambda: block_sparse_matmul(
        x, w, kidx, block=block, block_m=cfg["block_m"],
        interpret=interpret)


def block_sparse_matmul_problem(x_shape, w_shape, dtype, *,
                                max_live: int,
                                block: int = 128) -> dict[str, Any]:
    m, k = (int(v) for v in x_shape)
    _, n = (int(v) for v in w_shape)
    return {"m": m, "k": k, "n": n, "block": int(block),
            "max_live": int(max_live), "dtype": jnp.dtype(dtype).name}


KERNELS: dict[str, KernelEntry] = {
    "flash_attention": KernelEntry(
        "flash_attention", {"block_q": 128, "block_kv": 128},
        _fa_candidates, _fa_runner),
    "flash_decode": KernelEntry(
        "flash_decode", {"block_kv": 128},
        _fd_candidates, _fd_runner),
    "flash_decode_paged": KernelEntry(
        "flash_decode_paged", {"page_size": 16},
        _fpd_candidates, _fpd_runner),
    "paged_segment": KernelEntry(
        "paged_segment", {"segment_len": 8},
        _pseg_candidates, _pseg_runner),
    "flash_prefill_ragged": KernelEntry(
        "flash_prefill_ragged", {"block_q": BQ_PREFILL},
        _fpr_candidates, _fpr_runner),
    "quant_matmul": KernelEntry(
        "quant_matmul", {"block_m": BM, "block_n": BN, "block_k": BK},
        _qmm_candidates, _qmm_runner),
    "block_sparse_matmul": KernelEntry(
        "block_sparse_matmul", {"block_m": 128},
        _bsmm_candidates, _bsmm_runner),
}


# ------------------------------------------------------------------- tuning
def _fallback_timeit(fn, *, warmup: int = 1, iters: int = 5) -> float:
    """Same contract as benchmarks/common.py::timeit (median µs/call)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        for leaf in jax.tree.leaves(out):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _default_timer(fn, *, warmup: int, iters: int) -> float:
    try:
        from benchmarks.common import timeit
    except ImportError:
        return _fallback_timeit(fn, warmup=warmup, iters=iters)
    return timeit(fn, warmup=warmup, iters=iters)


def _config_distance(cfg: dict[str, int], default: dict[str, int]) -> float:
    return sum(abs(math.log2(cfg[k]) - math.log2(default[k]))
               for k in default)


def enumerate_candidates(kernel: str, problem: dict[str, Any], *,
                         vmem_budget: int = VMEM_BUDGET,
                         max_trials: int | None = None
                         ) -> list[tuple[dict[str, int], int]]:
    """Pruned candidate list for ``kernel`` on ``problem``.

    Divisibility-infeasible and VMEM-over-budget configs are dropped; the
    remainder is ordered default-first (distance in log2-tile space) and
    optionally capped at ``max_trials`` — the default config survives any
    cap, which is what guarantees tuned-never-slower-than-default.
    """
    entry = KERNELS[kernel]
    cands = [(c, v) for c, v in entry.candidates(problem)
             if v <= vmem_budget]
    cands.sort(key=lambda cv: (_config_distance(cv[0], entry.default_config),
                               sorted(cv[0].items())))
    if max_trials is not None:
        cands = cands[:max(1, max_trials)]
    return cands


def tune(kernel: str, problem: dict[str, Any], *,
         cache_path: str | None = None,
         force: bool = False,
         interpret: bool | None = None,
         iters: int = 3, warmup: int = 1,
         max_trials: int | None = 16,
         vmem_budget: int = VMEM_BUDGET,
         timer: Callable[..., float] | None = None) -> TuneResult:
    """Find (or recall) the best tile config for ``kernel`` on ``problem``.

    On a cache hit the measurement loop is skipped entirely; on a miss every
    surviving candidate is timed and the winner is persisted.
    """
    if kernel not in KERNELS:
        raise KeyError(f"unknown tunable kernel {kernel!r}; "
                       f"have {sorted(KERNELS)}")
    path = cache_path or default_cache_path()
    key = cache_key(kernel, problem)
    if not force:
        entry = _load(path)["entries"].get(key)
        # A cached entry only counts if it is evidence for THIS request:
        # same backend (CPU-interpret timings say nothing about the MXU),
        # at least as deep a search, and at least as many timing iters as
        # now requested (a shallow/noisy bench sweep must not permanently
        # shadow a fuller TUNE search).
        if entry is not None and entry.get("backend") == \
                jax.default_backend() and entry.get("iters", 0) >= iters \
                and entry.get("vmem_budget", float("inf")) <= vmem_budget:
            requested = len(enumerate_candidates(
                kernel, problem, vmem_budget=vmem_budget,
                max_trials=max_trials))
            if entry.get("n_trials", 0) >= requested:
                return TuneResult(kernel, key, dict(entry["config"]),
                                  float(entry["us"]), cached=True)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    timer = timer or _default_timer
    spec = KERNELS[kernel]
    cands = enumerate_candidates(kernel, problem, vmem_budget=vmem_budget,
                                 max_trials=max_trials)
    if not cands:
        raise ValueError(f"{kernel}: no feasible tile candidate for "
                         f"{problem} under vmem_budget={vmem_budget}")
    vmem_of = {json.dumps(c, sort_keys=True): v for c, v in cands}
    trials: list[Trial] = []

    def evaluate(cfg: dict[str, int]):
        vmem = vmem_of[json.dumps(cfg, sort_keys=True)]
        runner = spec.make_runner(problem, cfg, interpret)
        us = float(timer(runner, warmup=warmup, iters=iters))
        trials.append(Trial(dict(cfg), us, vmem))
        # maximize -latency; every pre-pruned candidate is feasible
        return True, -us, {"us": us, "vmem_bytes": vmem}

    search = exhaustive_search([c for c, _ in cands], evaluate)
    best_cfg, best_us = dict(search.best_x), -search.best_objective
    _store(path, key, {"config": best_cfg, "us": best_us,
                       "n_trials": len(trials), "iters": iters,
                       "vmem_budget": vmem_budget,
                       "backend": jax.default_backend(),
                       "t": time.time()})
    return TuneResult(kernel, key, best_cfg, best_us,
                      cached=False, trials=trials, search=search)


def cached_config(kernel: str, problem: dict[str, Any], *,
                  cache_path: str | None = None,
                  relax: tuple[str, ...] = ()) -> dict[str, int]:
    """Persisted tuned config for ``problem``, or the kernel default.

    Never tunes and never times — a pure (memoized) cache read, so it is
    safe on a model's trace path: layers consult it per kernel call to
    pick up whatever the TUNE task / ``tuned_*`` wrappers persisted,
    falling back to the default config on a miss or a backend mismatch.

    ``relax``: problem fields allowed to differ on fallback matching.  A
    TUNE run keys its decode problem on the arch's nominal cache length
    and a proxy batch, while serving builds ``prompt+gen+1``-length caches
    at the actual batch — relaxing ("b", "cache_len") lets the nearest
    tuned entry (log-distance over the relaxed dims) stand in, so tuning
    wins still reach serving shapes TUNE never saw exactly.  Configs stay
    valid across the relaxation because kernels clamp tiles to the
    problem dims.
    """
    cfg, _ = cached_config_info(kernel, problem, cache_path=cache_path,
                                relax=relax)
    return cfg


def cached_config_info(kernel: str, problem: dict[str, Any], *,
                       cache_path: str | None = None,
                       relax: tuple[str, ...] = ()
                       ) -> tuple[dict[str, int], str]:
    """:func:`cached_config` plus where the answer came from: ``"tuned"``
    (exact backend-matched hit), ``"relaxed"`` (nearest tuned entry over
    the relaxed fields), or ``"default"`` (kernel default on a miss).
    The provenance label is what a :class:`~repro.serving.plan.ServingPlan`
    records per resolved knob."""
    path = cache_path or default_cache_path()
    entries = _load(path)["entries"]
    entry = entries.get(cache_key(kernel, problem))
    if entry is not None and entry.get("backend") == jax.default_backend():
        return dict(entry["config"]), "tuned"
    if relax:
        strict = {k: v for k, v in problem.items() if k not in relax}
        prefix = f"{kernel}|"
        best: tuple[float, dict[str, Any]] | None = None
        for key, e in entries.items():
            if not key.startswith(prefix) or \
                    e.get("backend") != jax.default_backend():
                continue
            try:
                p = json.loads(key[len(prefix):])
            except ValueError:      # pragma: no cover - corrupt entry
                continue
            if {k: v for k, v in p.items() if k not in relax} != strict:
                continue
            dist = sum(abs(math.log(max(float(p.get(f, 1)), 1.0))
                           - math.log(max(float(problem.get(f, 1)), 1.0)))
                       for f in relax)
            if best is None or dist < best[0]:
                best = (dist, e)
        if best is not None:
            return dict(best[1]["config"]), "relaxed"
    return dict(KERNELS[kernel].default_config), "default"


# The one registry of relax keys per kernel: which problem fields a
# serving-time readback may differ from the TUNE run's proxy problem in
# (batch/slot count and cache length scale with deployment, tile choices
# don't).  Every cached-config consumer — pool construction
# (serving/plan.py resolve, paged_cache.preferred_*), the layer-dispatch
# sites in models/layers.py, and TUNE's problem derivation — goes through
# :func:`tile_readback` with this table instead of carrying its own copy
# of the relax tuple.
TILE_RELAX: dict[str, tuple[str, ...]] = {
    "flash_decode": ("b", "cache_len"),
    "flash_decode_paged": ("slots", "max_len"),
    "paged_segment": ("slots", "max_len"),
    "flash_prefill_ragged": ("slots", "s", "max_len"),
}


def tile_readback(kernel: str, problem: dict[str, Any], *,
                  cache_path: str | None = None
                  ) -> tuple[dict[str, int], str]:
    """Consolidated autotune-cache readback: ``cached_config`` under the
    kernel's registered :data:`TILE_RELAX` fields, returning
    ``(config, provenance)``.  Pure read — safe on the trace path."""
    return cached_config_info(kernel, problem, cache_path=cache_path,
                              relax=TILE_RELAX.get(kernel, ()))


_RESOLVED: dict[tuple, dict[str, int]] = {}   # per-process get_config memo


def get_config(kernel: str, problem: dict[str, Any],
               **tune_kwargs: Any) -> dict[str, int]:
    """Tuned config for ``problem``; tunes on cache miss.

    After the first call per process the lookup is a pure in-memory dict
    hit — no candidate enumeration, no file IO, no measurement — so
    routing every kernel call through here adds no measurable overhead.
    """
    memoizable = not tune_kwargs.get("force") \
        and "timer" not in tune_kwargs
    memo_key = (kernel, cache_key(kernel, problem),
                tune_kwargs.get("cache_path"),
                tune_kwargs.get("max_trials", 16),
                tune_kwargs.get("vmem_budget", VMEM_BUDGET),
                tune_kwargs.get("iters", 3))
    if memoizable and memo_key in _RESOLVED:
        return _RESOLVED[memo_key]
    cfg = tune(kernel, problem, **tune_kwargs).config
    if memoizable:
        _RESOLVED[memo_key] = cfg
    return cfg


# ------------------------------------------------------- tuned dispatchers
def tuned_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                          interpret: bool = False,
                          cache_path: str | None = None,
                          **tune_kwargs: Any):
    cfg = get_config(
        "flash_attention",
        flash_attention_problem(q.shape, k.shape, q.dtype,
                                causal=causal, window=window),
        cache_path=cache_path, **tune_kwargs)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret, block_q=cfg["block_q"],
                           block_kv=cfg["block_kv"])


def tuned_flash_decode(q, k, v, mask, *, interpret: bool = False,
                       cache_path: str | None = None,
                       **tune_kwargs: Any):
    cfg = get_config(
        "flash_decode",
        flash_decode_problem(q.shape, k.shape, q.dtype),
        cache_path=cache_path, **tune_kwargs)
    return flash_decode(q, k, v, mask, interpret=interpret,
                        block_kv=cfg["block_kv"])


def tuned_quant_matmul(x, w, *, interpret: bool = False,
                       out_dtype=jnp.float32,
                       cache_path: str | None = None,
                       **tune_kwargs: Any):
    cfg = get_config(
        "quant_matmul",
        quant_matmul_problem(x.shape, w.shape, x.dtype,
                             out_dtype=out_dtype),
        cache_path=cache_path, **tune_kwargs)
    return quant_matmul(x, w, interpret=interpret, out_dtype=out_dtype,
                        block_m=cfg["block_m"], block_n=cfg["block_n"],
                        block_k=cfg["block_k"])


def tuned_block_sparse_matmul(x, w, kindex, *, block: int = 128,
                              interpret: bool = False,
                              cache_path: str | None = None,
                              **tune_kwargs: Any):
    cfg = get_config(
        "block_sparse_matmul",
        block_sparse_matmul_problem(x.shape, w.shape, x.dtype,
                                    max_live=int(kindex.shape[1]),
                                    block=block),
        cache_path=cache_path, **tune_kwargs)
    return block_sparse_matmul(x, w, kindex, block=block,
                               block_m=cfg["block_m"], interpret=interpret)
