"""Pallas TPU kernel: dynamic-activation int8 x int8 matmul.

Executes the QUANTIZATION O-task's int8 policy on the MXU (DESIGN.md §2).
The activation is quantized per-row on the fly (absmax/127), the weight
arrives pre-quantized per-output-channel; accumulation is int32 in VMEM and
dequantization happens once per output tile.

Tiling: out tile (BM=128, BN=128), contraction loop in BK=512 slabs — MXU
dims are multiples of 128, the int8 MXU path packs 2x per pass.  Working
set per grid step: BM*BK + BK*BN int8 + BM*BN int32 ≈ 128KB + 64KB ≪ VMEM.
All three tile dims are overridable per call (``block_m``/``block_n``/
``block_k``) and autotuned per shape by kernels/autotune.py.

``ref.py`` holds the pure-jnp oracle; tests sweep shapes/dtypes with
interpret=True (CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 512


def _qmm_kernel(xq_ref, xs_ref, wq_ref, ws_ref, out_ref, acc_ref, *,
                k_steps: int):
    """Grid: (m_tiles, n_tiles, k_steps); k is the innermost loop."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = xq_ref[...]
    w = wq_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _finish():
        acc = acc_ref[...].astype(jnp.float32)
        out_ref[...] = (acc * xs_ref[...] * ws_ref[...]
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype",
                                             "block_m", "block_n",
                                             "block_k"))
def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                 interpret: bool = False,
                 out_dtype=jnp.float32,
                 block_m: int | None = None,
                 block_n: int | None = None,
                 block_k: int | None = None) -> jnp.ndarray:
    """x: (M, K) float; w: (K, N) float.  Returns (M, N) ~= x @ w computed
    through the int8 MXU path.  ``block_*`` override the default
    (128, 128, 512) tiling (autotuned via kernels/autotune.py)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    # host-side quantization (weights would be pre-quantized in practice)
    xf = x.astype(jnp.float32)
    xs = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True), 1e-8) \
        / 127.0
    xq = jnp.clip(jnp.round(xf / xs), -127, 127).astype(jnp.int8)
    wf = w.astype(jnp.float32)
    ws = jnp.maximum(jnp.max(jnp.abs(wf), axis=0, keepdims=True), 1e-8) \
        / 127.0
    wq = jnp.clip(jnp.round(wf / ws), -127, 127).astype(jnp.int8)

    bm, bn = min(block_m or BM, m), min(block_n or BN, n)
    bk = min(block_k or BK, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shapes ({m},{k})x({k},{n}) not tileable by ({bm},{bn},{bk})"
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, xs, wq, ws)
    return out
