"""Pallas TPU kernel: flash attention (causal / sliding-window / GQA).

The TPU-optimized form of models/layers.py::mea_attention (same online-
softmax math; that function is the pure-jnp oracle).  Tiling: q tile
``block_q`` x kv tile ``block_kv`` (default 128x128, tunable — see
kernels/autotune.py); (m, l, acc) live in VMEM scratch across the kv-loop
(innermost grid dim), so HBM traffic is O(S) per q tile instead of O(S^2) —
this is what moves the 32k-prefill memory roofline term (EXPERIMENTS.md
§Perf).

Causal skipping: kv tiles strictly above the diagonal are skipped via
pl.when (no MXU work is issued), recovering the ~2x causal FLOP saving that
the naive jnp path wastes.

GQA: q heads are mapped onto their kv head inside the BlockSpec index maps
(``kv_bh = batch * kv_heads + q_head // group``), so repeated K/V tiles are
re-read from the *same* HBM block instead of materializing a g-times larger
repeated tensor (g x HBM traffic + footprint saved vs the old jnp.repeat
path).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BQ, BKV = 128, 128


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, scale: float, causal: bool, window: int,
                  bq: int, bkv: int, seq_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    kv_start = kj * bkv
    if causal:  # skip tiles strictly above the diagonal
        run = kv_start <= q_start + bq - 1
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv),
                                                     1)
        mask = kv_pos < seq_kv
        if causal:
            mask &= q_pos >= kv_pos
        if window:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _finish():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(
                          out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "interpret", "block_q",
                                             "block_kv"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    interpret: bool = False,
                    block_q: int | None = None,
                    block_kv: int | None = None) -> jnp.ndarray:
    """q: (B,Sq,H,D); k/v: (B,Skv,KV,D) with H % KV == 0.
    Returns (B,Sq,H,D).  ``block_q``/``block_kv`` override the default
    128x128 tiling (autotuned via kernels/autotune.py)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    # fold batch*heads, pad seq to tile multiples.  K/V keep their kv heads:
    # the BlockSpec index maps below fold the q-head -> kv-head mapping, so
    # GQA never materializes repeated K/V in HBM.
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    bq = min(block_q or BQ, sq)
    bkv = min(block_kv or BKV, skv)
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    kv_steps = skv_p // bkv
    grid = (b * h, sq_p // bq, kv_steps)

    def kv_map(bh, i, j):
        # bh = batch * h + q_head  ->  batch * kvh + q_head // g
        return ((bh // h) * kvh + (bh % h) // g, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps,
                          scale=1.0 / math.sqrt(d), causal=causal,
                          window=window, bq=bq, bkv=bkv, seq_kv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out
