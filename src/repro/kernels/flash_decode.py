"""Pallas TPU kernel: flash-decoding attention for one generated token.

The decode-side mirror of kernels/flash_attention.py: a single query
position attends over the whole KV cache.  The cache sequence axis is
split into ``block_kv`` tiles (the kv-split grid of flash-decoding) and
the innermost grid dimension reduces them with the partial-softmax
(m, l, acc) carry held in VMEM scratch, so HBM reads the cache exactly
once per step regardless of the split.  K/V are consumed in their native
``(B, L, KV, D)`` cache layout via the BlockSpec index maps — no
transposed or repeated copy of the cache is ever materialized.  Requested
splits are snapped divisor-safe (:func:`pick_block_kv`), so the pad-tail
cache copy only exists for caches too long to take in a single tile whose
length no candidate divides.

GQA: instead of expanding K/V ``g = H // KV`` times (the jnp oracle's
``_expand_kv``/``jnp.repeat``, which copies the cache g x per generated
token), the q heads sharing one kv head are folded into the *rows* of the
q tile: q is reshaped ``(B, H, D) -> (B, KV, g, D)`` (pure metadata) so
each grid cell computes a ``(g, block_kv)`` score panel against a K/V
tile that is read from HBM once.

Masking: the kernel takes a precomputed ``(L,)`` validity mask instead of
deriving positions internally.  Callers build it from
``models/layers.py::kv_positions_for_cache`` — the one place that knows
how to recover absolute positions from both the linear cache and the
sliding-window ring buffer — so the kernel and the jnp oracle can never
disagree about which slots are live.  Tiles with no live slot skip their
MXU work entirely (``pl.when``), which prunes the empty tail of a
freshly-prefilled linear cache.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BKV = 128
MAX_SINGLE_TILE = 1024


def pick_block_kv(block_kv: int | None, skv: int) -> int:
    """Divisor-safe kv-split for a cache of length ``skv``.

    A ragged split pads a fresh copy of the whole cache on every decode
    step (the cache changes per step, so the pad cannot be hoisted out of
    the generation scan).  Snap instead: clamp to the cache length, and
    when the tile still does not divide, take the cache in one tile if
    that fits comfortably in VMEM — only a giant ragged cache ever pays
    the pad-tail copy.
    """
    bkv = min(block_kv or BKV, skv)
    if skv % bkv == 0:
        return bkv
    if skv <= MAX_SINGLE_TILE:
        return skv
    return bkv


def online_softmax_init(m_ref, l_ref, acc_ref) -> None:
    """Reset the (m, l, acc) partial-softmax carry at the first kv step.

    Shared with kernels/flash_decode_paged.py so the numerically
    sensitive online-softmax update cannot drift between the contiguous
    and paged decode kernels.
    """
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def online_softmax_step(q_ref, k_ref, v_ref, live,
                        m_ref, l_ref, acc_ref, *, scale: float) -> None:
    """Accumulate one K/V tile into the (m, l, acc) carry.

    q_ref: (1, 1, g, d) grouped-q tile; k/v_ref: (1, tile, 1, d);
    live: (1, tile) bool validity of the tile's cache slots.
    """
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (tile, d)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(live, s, NEG_INF)                    # (g, tile)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def online_softmax_finish(out_ref, m_ref, l_ref, acc_ref) -> None:
    """Write the normalized accumulator after the last kv step."""
    del m_ref
    out_ref[0, 0] = (acc_ref[...]
                     / jnp.maximum(l_ref[...], 1e-30)).astype(
                         out_ref.dtype)


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref,
                   m_ref, l_ref, acc_ref, *, kv_steps: int, scale: float):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    live = mask_ref[...] != 0                          # (1, bkv)

    @pl.when(jnp.any(live))
    def _step():
        online_softmax_step(q_ref, k_ref, v_ref, live,
                            m_ref, l_ref, acc_ref, scale=scale)

    @pl.when(kj == kv_steps - 1)
    def _finish():
        online_softmax_finish(out_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret", "block_kv"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 mask: jnp.ndarray, *, interpret: bool = False,
                 block_kv: int | None = None) -> jnp.ndarray:
    """q: (B,1,H,D); k/v: (B,L,KV,D) with H % KV == 0; mask: (L,) bool —
    True where the cache slot participates (shared across the batch: the
    decode position is a scalar).  Returns (B,1,H,D).  ``block_kv`` sets
    the kv-split tile (autotuned via kernels/autotune.py)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    assert sq == 1, f"flash_decode is single-token (got sq={sq})"
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    bkv = pick_block_kv(block_kv, skv)
    pad = (-skv) % bkv
    # group q heads by their kv head: rows of one q tile share a K/V tile
    qf = q[:, 0].reshape(b, kvh, g, d)
    mf = mask.astype(jnp.int32).reshape(1, skv)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mf = jnp.pad(mf, ((0, 0), (0, pad)))           # padding is masked
    kv_steps = (skv + pad) // bkv
    grid = (b, kvh, kv_steps)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, kv_steps=kv_steps,
                          scale=1.0 / math.sqrt(d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, kv, j: (bi, kv, 0, 0)),
            pl.BlockSpec((1, bkv, 1, d), lambda bi, kv, j: (bi, j, kv, 0)),
            pl.BlockSpec((1, bkv, 1, d), lambda bi, kv, j: (bi, j, kv, 0)),
            pl.BlockSpec((1, bkv), lambda bi, kv, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, kv, j: (bi, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v, mf)
    return out.reshape(b, 1, h, d)
