"""Pallas TPU kernel: block-sparse matmul over a static 128x128 block mask.

Executes the PRUNING O-task's block masks: a zero weight block is the TPU
analogue of a deleted DSP on a fully-unrolled FPGA design (DESIGN.md §2).
The mask is known at compile time (pruning is a training-time decision), so
the grid loops over a *compacted* per-output-column list of live k-blocks
(host-precomputed, -1 padded): the trip count is ``max_live`` (densest
column), not ``k_blocks`` — compute drops structurally with block sparsity.

Data-dependent tile selection uses the TPU scalar-prefetch mechanism
(PrefetchScalarGridSpec): the live-block index array is prefetched to SMEM
and drives the x/w BlockSpec index maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 128


def _bsmm_kernel(kidx_ref, x_ref, w_ref, out_ref, acc_ref, *, steps: int):
    t = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = kidx_ref[j, t] >= 0

    @pl.when(live)
    def _step():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(t == steps - 1)
    def _finish():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def compact_block_index(block_map: np.ndarray) -> np.ndarray:
    """(kb, nb) 0/1 occupancy → (nb, max_live) k-block indices, -1 padded."""
    kb, nb = block_map.shape
    cols = [np.nonzero(block_map[:, j])[0] for j in range(nb)]
    max_live = max([len(c) for c in cols] + [1])
    out = -np.ones((nb, max_live), np.int32)
    for j, c in enumerate(cols):
        out[j, :len(c)] = c
    return out


@functools.partial(jax.jit, static_argnames=("interpret", "block",
                                             "block_m"))
def block_sparse_matmul(x: jnp.ndarray, w: jnp.ndarray,
                        kindex: jnp.ndarray, *,
                        block: int = BLOCK,
                        block_m: int | None = None,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (M, K); w: (K, N) (already masked); kindex: (N/block, max_live)
    from :func:`compact_block_index`.  Returns x @ w over live blocks.

    ``block`` is the mask granularity (fixed by the kindex layout);
    ``block_m`` is the free M-tile dimension (autotuned via
    kernels/autotune.py)."""
    m, k = x.shape
    _, n = w.shape
    bm = min(block_m or block, m)
    assert m % bm == 0 and k % block == 0 and n % block == 0
    nb = n // block
    steps = int(kindex.shape[1])
    grid = (m // bm, nb, steps)

    def x_map(i, j, t, kidx):
        return (i, jnp.maximum(kidx[j, t], 0))

    def w_map(i, j, t, kidx):
        return (jnp.maximum(kidx[j, t], 0), j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block), x_map),
            pl.BlockSpec((block, block), w_map),
        ],
        out_specs=pl.BlockSpec((bm, block), lambda i, j, t, kidx: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, block), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_bsmm_kernel, steps=steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(kindex, x, w)
    return out
