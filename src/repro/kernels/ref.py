"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Same quantization math as the kernel, plain jnp."""
    xf = x.astype(jnp.float32)
    xs = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True), 1e-8) \
        / 127.0
    xq = jnp.round(jnp.clip(xf / xs, -127, 127)).astype(jnp.int32)
    wf = w.astype(jnp.float32)
    ws = jnp.maximum(jnp.max(jnp.abs(wf), axis=0, keepdims=True), 1e-8) \
        / 127.0
    wq = jnp.round(jnp.clip(wf / ws, -127, 127)).astype(jnp.int32)
    # exact int32 accumulation — matches the kernel bit-for-bit
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs * ws


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """Naive softmax attention with GQA/causal/window semantics."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def block_sparse_matmul_ref(x: jnp.ndarray, w_masked: jnp.ndarray
                            ) -> jnp.ndarray:
    """Dense reference over the (already masked) weight."""
    return x.astype(jnp.float32) @ w_masked.astype(jnp.float32)
