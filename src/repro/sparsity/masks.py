"""Pruning masks (PRUNING O-task substrate).

Paper §V-B: the PRUNING O-task "gradually zeroes out weights during training
to create a more compact and efficient network while maintaining accuracy",
with auto-pruning maximizing the rate subject to ``accuracy_loss <= alpha_p``.

TPU adaptation (DESIGN.md §2): on a fully-unrolled FPGA design a zero weight
deletes a DSP; on a TPU only *structured* zeros buy anything.  We support two
granularities:

- ``unstructured``: classic magnitude pruning (reproduces the paper's
  accuracy/rate curves; resource proxy counts effective MACs).
- ``block``: 128x128-block magnitude pruning (MXU tile granularity); zero
  blocks are skipped by the block-sparse Pallas kernel, so the compute-term
  saving is structural, not cosmetic.

Masks are pytrees parallel to (a subset of) the param pytree, {path: 0/1
array}.  The polynomial schedule mirrors the Keras pruning API the paper
uses (gradually ramping sparsity during fine-tuning).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128  # MXU tile edge


def magnitude_mask(w: jnp.ndarray, rate: float) -> jnp.ndarray:
    """Unstructured magnitude mask keeping the top (1-rate) fraction."""
    if rate <= 0.0:
        return jnp.ones_like(w, dtype=jnp.float32)
    flat = jnp.abs(w).astype(jnp.float32).reshape(-1)
    k = int(round((1.0 - rate) * flat.size))
    if k <= 0:
        return jnp.zeros_like(w, dtype=jnp.float32)
    thresh = jnp.sort(flat)[flat.size - k]
    return (jnp.abs(w).astype(jnp.float32) >= thresh).astype(jnp.float32)


def block_mask(w: jnp.ndarray, rate: float, block: int = BLOCK
               ) -> jnp.ndarray:
    """Block-granular magnitude mask for a 2-D weight.

    Blocks are scored by their L1 norm; the lowest-norm ``rate`` fraction of
    blocks is zeroed.  The returned mask is full-resolution (same shape as
    ``w``) so it can also be consumed by the dense masked path; the
    block-sparse kernel re-derives the block map from it.
    """
    assert w.ndim == 2, "block masks are for 2-D weights"
    m, n = w.shape
    bm, bn = -(-m // block), -(-n // block)
    pad = jnp.zeros((bm * block, bn * block), w.dtype).at[:m, :n].set(w)
    blocks = pad.reshape(bm, block, bn, block)
    scores = jnp.sum(jnp.abs(blocks.astype(jnp.float32)), axis=(1, 3))
    flat = scores.reshape(-1)
    k = int(round((1.0 - rate) * flat.size))
    if k <= 0:
        return jnp.zeros((m, n), jnp.float32)
    thresh = jnp.sort(flat)[flat.size - k]
    bmask = (scores >= thresh).astype(jnp.float32)  # (bm, bn)
    full = jnp.repeat(jnp.repeat(bmask, block, axis=0), block, axis=1)
    return full[:m, :n]


def block_map(mask: jnp.ndarray, block: int = BLOCK) -> np.ndarray:
    """(bm, bn) 0/1 block occupancy map from a full-resolution mask."""
    m, n = mask.shape
    bm, bn = -(-m // block), -(-n // block)
    pad = np.zeros((bm * block, bn * block), np.float32)
    pad[:m, :n] = np.abs(np.asarray(mask, np.float32))
    return (pad.reshape(bm, block, bn, block).sum(axis=(1, 3)) > 0
            ).astype(np.int32)


def polynomial_schedule(step: int, begin: int, end: int,
                        final_rate: float, power: float = 3.0) -> float:
    """Keras-style polynomial-decay sparsity ramp (0 → final_rate)."""
    if step <= begin:
        return 0.0
    if step >= end:
        return final_rate
    frac = (step - begin) / max(1, end - begin)
    return final_rate * (1.0 - (1.0 - frac) ** power)


def prunable_paths(params, min_size: int = 1024,
                   exempt: tuple[str, ...] = ("embed", "router", "norm",
                                              "bias", "scale", "gate_logit",
                                              "dt_", "A_log")) -> list[str]:
    """Paths of 2-D weights worth pruning (skips tiny/exempt tensors)."""
    flat = flatten_params(params)
    out = []
    for path, leaf in flat.items():
        if leaf.ndim != 2 or leaf.size < min_size:
            continue
        if any(tok in path for tok in exempt):
            continue
        out.append(path)
    return sorted(out)


def build_masks(params, rate: float, granularity: str = "block",
                paths: list[str] | None = None,
                block: int = BLOCK) -> dict[str, jnp.ndarray]:
    """{path: mask} for the selected (or all prunable) paths."""
    flat = flatten_params(params)
    paths = paths if paths is not None else prunable_paths(params)
    fn = block_mask if granularity == "block" else (
        lambda w, r: magnitude_mask(w, r))
    masks = {}
    for p in paths:
        w = flat[p]
        masks[p] = fn(w, rate) if granularity != "block" else block_mask(
            w, rate, block)
    return masks


def apply_masks(params, masks: dict[str, jnp.ndarray]):
    """Multiply masked weights into a new param pytree."""
    flat = flatten_params(params)
    for p, m in masks.items():
        flat[p] = (flat[p].astype(jnp.float32) * m).astype(flat[p].dtype)
    return unflatten_params(flat)


def sparsity_report(masks: dict[str, jnp.ndarray]) -> dict[str, float]:
    total = sum(int(m.size) for m in masks.values())
    zeros = sum(int(m.size) - int(jnp.sum(m)) for m in masks.values())
    return {"masked_params": total, "zeros": zeros,
            "sparsity": zeros / max(1, total)}


# --------------------------------------------------------- pytree helpers
def flatten_params(params) -> dict[str, jnp.ndarray]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else k, v)
        else:
            flat[prefix] = node

    rec("", params)
    return flat


def unflatten_params(flat: dict[str, jnp.ndarray]):
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def effective_macs_fraction(masks: dict[str, jnp.ndarray],
                            params) -> float:
    """Fraction of matmul MACs surviving pruning — the DSP-usage analogue."""
    flat = flatten_params(params)
    total = sum(int(flat[p].size) for p in masks)
    if total == 0:
        return 1.0
    alive = sum(float(jnp.sum(m)) for m in masks.values())
    return alive / total
