"""Attention (GQA / MLA / SWA), MLPs, and expert-parallel MoE.

All apply functions take a :class:`repro.models.common.Ctx` so the
QUANTIZATION O-task's policy reaches every matmul, and the mesh reaches the
shard_map-based expert-parallel MoE.

Attention has two execution paths:
- prefill/train: chunked memory-efficient attention (scan over kv chunks,
  online softmax) — bounded VMEM/HBM footprint for the 32k shapes; the
  Pallas flash kernel (kernels/flash_attention.py) is the TPU-optimized
  equivalent, validated against the same math.
- decode: single-token attention against a KV cache.  Caches shard their
  *sequence* axis over the ``model`` mesh axis (flash-decoding style):
  GSPMD turns the softmax/combine reductions into tiny cross-shard
  collectives instead of all-gathering the cache.  Under
  ``ctx.use_kernels`` (and an unsharded cache sequence axis) decode runs
  in the flash_decode Pallas kernel (kernels/flash_decode.py) — kv-split
  partial softmax, GQA without K/V expansion; the jnp path stays as its
  oracle and as the path GSPMD partitions when the cache is seq-sharded.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models.common import (Ctx, act_fn, apply_rope, dense_init,
                                 init_norm, linear, norm_apply)
from repro.quant.policy import INT8, quantize_int8

if hasattr(jax, "shard_map"):  # jax>=0.6
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # type: ignore

# The replication-check kwarg was renamed check_rep -> check_vma across
# JAX versions; feature-detect against the installed signature so the
# _moe_ep shard_map works on either side of the rename.
import inspect as _inspect

_SHARD_MAP_CHECK_KW = next(
    (kw for kw in ("check_vma", "check_rep")
     if kw in _inspect.signature(shard_map).parameters), None)


def _shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """shard_map with the replication/VMA check disabled, version-portably."""
    kw = {_SHARD_MAP_CHECK_KW: False} if _SHARD_MAP_CHECK_KW else {}
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)

NEG_INF = -1e30


# =====================================================================
# Attention
# =====================================================================
def init_attention(key, cfg: ArchConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    dt = cfg.pdt
    params: dict[str, Any] = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt, scale=1.0 / math.sqrt(h * hd)),
    }
    axes: dict[str, Any] = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        params.update(bq=jnp.zeros((h * hd,), dt),
                      bk=jnp.zeros((kv * hd,), dt),
                      bv=jnp.zeros((kv * hd,), dt))
        axes.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    if cfg.qk_norm:
        qn, qax = init_norm(cfg.norm, hd, dt)
        kn, kax = init_norm(cfg.norm, hd, dt)
        params.update(q_norm=qn, k_norm=kn)
        axes.update(q_norm={k: ("head_dim",) for k in qn},
                    k_norm={k: ("head_dim",) for k in kn})
    return params, axes


def _qkv(ctx: Ctx, cfg: ArchConfig, p, x):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    b, s, _ = x.shape
    q = linear(ctx, "attn/wq", x, p["wq"], p.get("bq"))
    k = linear(ctx, "attn/wk", x, p["wk"], p.get("bk"))
    v = linear(ctx, "attn/wv", x, p["wv"], p.get("bv"))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = norm_apply(cfg.norm, p["q_norm"], q)
        k = norm_apply(cfg.norm, p["k_norm"], k)
    return q, k, v


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,S,KV,hd) -> (B,S,H,hd) by group repetition."""
    b, s, kvh, hd = k.shape
    g = n_heads // kvh
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def kv_positions_for_cache(pos, cache_len: int,
                           sliding_window: int) -> jnp.ndarray:
    """Absolute position held by each decode-cache slot (2**30 = empty).

    Linear cache: slot i holds position i for i <= pos.  Sliding-window
    ring buffer: the current token lands at ``pos % cache_len`` and older
    slots wrap, so absolute positions are recovered from the write index;
    slots that would map to negative positions were never written.

    The single source of truth for cache-slot positions — shared by the
    jnp decode oracle and the flash_decode kernel's mask construction so
    the two paths cannot drift.
    """
    slot = jnp.arange(cache_len)
    if sliding_window:
        idx = pos % cache_len
        kv_pos = jnp.where(slot <= idx, pos - idx + slot,
                           pos - idx - cache_len + slot)
        return jnp.where(kv_pos >= 0, kv_pos, 2**30)
    return jnp.where(slot <= pos, slot, 2**30)


def decode_attention_mask(kv_pos: jnp.ndarray, pos,
                          sliding_window: int) -> jnp.ndarray:
    """(cache_len,) bool: which cache slots the token at ``pos`` attends."""
    mask = (kv_pos <= pos) & (kv_pos < 2**30)
    if sliding_window:
        mask &= (pos - kv_pos) < sliding_window
    return mask


def paged_kv_positions(seq_lens: jnp.ndarray, n_slots: int) -> jnp.ndarray:
    """(R, n_slots) absolute position held by each logical paged-cache slot.

    The paged variant of :func:`kv_positions_for_cache`: pages are
    logically contiguous per request (block tables hide the physical
    scatter), so slot ``i`` of request ``r`` holds position ``i`` when
    ``i <= seq_lens[r]`` (the slot at ``seq_lens[r]`` is the current
    token, written before attention) and is empty (2**30) past it.
    ``seq_lens`` is per-request — rows are ragged by construction.  The
    single source of truth shared by the jnp paged oracle and the
    flash_decode_paged kernel's mask, so the two cannot drift.
    """
    slot = jnp.arange(n_slots)
    return jnp.where(slot[None, :] <= seq_lens[:, None], slot[None, :],
                     2**30)


def paged_decode_attention_mask(kv_pos: jnp.ndarray,
                                seq_lens: jnp.ndarray) -> jnp.ndarray:
    """(R, n_slots) bool: slots each request's current token attends."""
    return (kv_pos <= seq_lens[:, None]) & (kv_pos < 2**30)


def ragged_prefill_positions(offsets: jnp.ndarray, s: int) -> jnp.ndarray:
    """(R, s) absolute position of each suffix query token.

    Batched ragged admission prefill computes only the tokens *after*
    each request's shared prefix; ``offsets[r]`` is the prefix length, so
    suffix token ``i`` of request ``r`` sits at ``offsets[r] + i``.  The
    single source of truth for suffix positions — lm_apply feeds it to
    rope, and the mask helper below derives the causal frontier from it.
    """
    return offsets[:, None] + jnp.arange(s)[None]


def ragged_prefill_attention_mask(offsets: jnp.ndarray, lens: jnp.ndarray,
                                  s: int, n_slots: int) -> jnp.ndarray:
    """(R, s, n_slots) bool: paged-cache slots each suffix query attends.

    Logical slot ``j`` of a request holds position ``j`` (block tables
    hide the physical scatter); query ``i`` at position ``offsets[r]+i``
    attends every slot at or before it — the shared prefix written by an
    earlier admission plus its own suffix, scattered in the same dispatch
    before attention.  Rows at or past ``lens[r]`` (padding, idle slots)
    attend nothing.  The flash_prefill_ragged kernel derives the same
    predicate in-kernel from the scalar-prefetched offsets/lens
    (tests/test_paged.py pins the two against each other).
    """
    q_pos = ragged_prefill_positions(offsets, s)
    valid_q = jnp.arange(s)[None] < lens[:, None]
    slot = jnp.arange(n_slots)
    return (slot[None, None, :] <= q_pos[:, :, None]) & valid_q[:, :, None]


def _masked_decode_attention(q, k, v, mask, n_heads: int) -> jnp.ndarray:
    """jnp one-token decode attention oracle.

    q: (B, 1, H, hd) over K/V (B, L, KV, hd); mask: (L,) batch-shared
    (contiguous cache — the decode position is a scalar) or (B, L)
    per-request (paged cache — ragged batch).  One implementation shared
    by the contiguous and paged decode branches so the oracle math the
    flash kernels are validated against cannot drift between cache
    layouts.
    """
    hd = q.shape[-1]
    k_exp = _expand_kv(k, n_heads)
    v_exp = _expand_kv(v, n_heads)
    scale = 1.0 / math.sqrt(hd)
    sgl = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                     k_exp.astype(jnp.float32))
    m2 = mask if mask.ndim == 2 else mask[None]
    sgl = jnp.where(m2[:, None, None, :], sgl, NEG_INF)
    w = jax.nn.softmax(sgl, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v_exp.astype(jnp.float32)).astype(q.dtype)


def mea_attention(q, k, v, q_positions, kv_positions, *,
                  causal: bool, window: int = 0, chunk: int = 1024,
                  bias: jnp.ndarray | None = None,
                  bf16_operands: bool = False) -> jnp.ndarray:
    """Chunked memory-efficient attention with online softmax.

    q: (B,Sq,H,hd); k/v: (B,Skv,H,hd) (kv already head-expanded).
    Scans over kv chunks carrying (m, l, acc) — the jnp oracle for the
    Pallas flash kernel.

    ``q_positions`` is (Sq,) batch-shared, or (B, Sq) per-request for the
    ragged paged-prefill oracle (each admission's suffix starts at its
    own shared-prefix offset).
    """
    b, sq, h, hd = q.shape
    hd_v = v.shape[-1]
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=2**30)
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd_v).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, chunk)

    # bf16 operands halve the dominant HBM traffic of the score/PV einsums;
    # accumulation stays fp32 (preferred_element_type) — §Perf knob.
    op_dt = jnp.bfloat16 if bf16_operands else jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(op_dt)

    # (Bm, Sq) query positions: Bm = B for per-request ragged rows, 1 for
    # the batch-shared case (broadcasts below exactly as mask[None] did)
    qp = q_positions if q_positions.ndim == 2 else q_positions[None]

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(op_dt),
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((qp.shape[0], sq, chunk), bool)
        if causal:
            mask &= qp[:, :, None] >= pj[None, None, :]
        if window:
            mask &= (qp[:, :, None] - pj[None, None, :]) < window
        mask &= pj[None, None, :] < 2**30
        s = jnp.where(mask[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(op_dt), vj.astype(op_dt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,hd)


def attention(ctx: Ctx, cfg: ArchConfig, p, x, positions,
              cache: dict | None = None,
              kv_override: tuple | None = None,
              causal: bool = True):
    """Full attention layer.  Returns (y, new_cache).

    - train/prefill: ``cache is None`` → chunked MEA over the sequence; a
      supplied cache is *filled* (prefill).
    - decode (``ctx.decode`` and cache given): x is (B,1,d); k/v written at
      ``cache['pos']`` (ring-buffered under sliding-window), then one-token
      attention over the seq-sharded cache — GSPMD emits flash-decoding
      partial-softmax collectives.
    - ``kv_override``: (k, v, kv_positions) — cross-attention (never causal,
      never cached here; the caller caches encoder K/V).

    ``positions``: (S,) absolute positions of the query tokens (decode: the
    single current position).
    """
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q, k, v = _qkv(ctx, cfg, p, x)
    if kv_override is not None:
        k, v, kv_pos = kv_override
        k_exp = _expand_kv(k, h)
        v_exp = _expand_kv(v, h)
        out = mea_attention(q, k_exp, v_exp, positions, kv_pos,
                            causal=False, chunk=cfg.attn_chunk,
                            bf16_operands=cfg.mea_bf16)
        y = linear(ctx, "attn/wo", out.reshape(b, s, h * hd), p["wo"])
        return y, cache

    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and ctx.decode and "k_pages" in cache:
        if "prefill_lens" in cache:
            # batched ragged admission prefill: s suffix tokens per
            # request, offsets/lens injected by the serving engine
            out, new_cache = _paged_attention_prefill(ctx, cfg, q, k, v,
                                                      cache)
        else:
            out, new_cache = _paged_attention_decode(ctx, cfg, q, k, v,
                                                     cache)
    elif cache is not None and ctx.decode:
        cache_len = cache["k"].shape[1]
        pos = cache["pos"]  # scalar int32: absolute position of x[:, 0]
        idx = pos % cache_len if cfg.sliding_window else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        kv_pos = kv_positions_for_cache(pos, cache_len, cfg.sliding_window)
        mask = decode_attention_mask(kv_pos, pos, cfg.sliding_window)
        # the pallas_call carries no partitioning rule, so the kernel only
        # dispatches when the cache's sequence axis is unsharded; a
        # model-axis-sharded cache keeps the jnp path, whose reductions
        # GSPMD turns into the flash-decoding cross-shard collectives
        seq_sharded = (ctx.mesh is not None
                       and "model" in ctx.mesh.axis_names
                       and _axis_size(ctx.mesh, "model") > 1)
        if ctx.use_kernels and s == 1 and not seq_sharded:
            # flash-decoding Pallas kernel: kv-split partial softmax, GQA
            # without the g x K/V copies of _expand_kv.  The kv-split
            # comes from the autotuner's persisted cache when TUNE has
            # covered this decode shape — nearest tuned cache length
            # stands in otherwise (a pure cache read — no tuning happens
            # on the trace path).
            from repro.kernels import autotune
            from repro.kernels.flash_decode import flash_decode
            tile, _ = autotune.tile_readback(
                "flash_decode",
                autotune.flash_decode_problem(q.shape, ck.shape, q.dtype))
            out = flash_decode(q, ck, cv, mask, interpret=ctx.interpret,
                               block_kv=tile["block_kv"]).astype(x.dtype)
        else:
            out = _masked_decode_attention(q, ck, cv, mask, h)
    else:
        k_exp = _expand_kv(k, h)
        v_exp = _expand_kv(v, h)
        out = mea_attention(q, k_exp, v_exp, positions, positions,
                            causal=causal, window=cfg.sliding_window,
                            chunk=cfg.attn_chunk,
                            bf16_operands=cfg.mea_bf16)
        if cache is not None:  # prefill fills the cache
            cache_len = cache["k"].shape[1]
            kk, vv = (k, v) if s <= cache_len else (k[:, -cache_len:],
                                                    v[:, -cache_len:])
            if cfg.sliding_window and s > cache_len:
                # ring layout: position p lives at slot p % cache_len.
                # The retained tail starts at position s - cache_len, so
                # rotate it into place — otherwise the first decode's
                # kv_positions_for_cache recovery reads the wrong slots
                # whenever s % cache_len != 0.
                shift = s % cache_len
                kk = jnp.roll(kk, shift, axis=1)
                vv = jnp.roll(vv, shift, axis=1)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], kk.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], vv.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv, "pos": jnp.asarray(s, jnp.int32)}
    y = linear(ctx, "attn/wo", out.reshape(b, s, h * hd), p["wo"])
    return y, new_cache


def _paged_attention_decode(ctx: Ctx, cfg: ArchConfig, q, k, v, cache):
    """One-token attention over a paged block-table cache (ragged batch).

    q/k/v: (R, 1, ·, hd) — already roped with per-request positions.  The
    per-layer cache carries the page pool (``k_pages``/``v_pages``:
    (P, page_size, KV, hd)) plus the batch-shared ``block_tables`` (R, M)
    and ``seq_lens`` (R,) injected by lm_apply.  The current token's K/V
    is scattered into page ``block_tables[r, seq_lens[r] // ps]`` before
    attention, then each request attends its own prefix — the jnp oracle
    gathers pages through the block table, the Pallas kernel
    (kernels/flash_decode_paged.py) dereferences it per grid step.  Both
    consume the same paged_kv_positions/paged_decode_attention_mask, so
    they cannot disagree about live slots.  Sliding-window ring layouts
    are not paged (the serving engine gates on ``cfg.sliding_window``).
    """
    assert not cfg.sliding_window, \
        "paged decode supports linear caches only"
    kp, vp = cache["k_pages"], cache["v_pages"]
    bt, sl = cache["block_tables"], cache["seq_lens"]
    _, ps, kvh, hd = kp.shape
    r, _, h, _ = q.shape
    blocks = bt.shape[1]
    n_slots = blocks * ps
    # write this token's k/v at the per-request write position.  The
    # clamp only ever bites for slots the engine has parked on its
    # scratch page (capacity for live requests is sized at admission).
    pos_w = jnp.minimum(sl, n_slots - 1)
    pidx = bt[jnp.arange(r), pos_w // ps]
    slot = pos_w % ps
    kp = kp.at[pidx, slot].set(k[:, 0].astype(kp.dtype))
    vp = vp.at[pidx, slot].set(v[:, 0].astype(vp.dtype))
    new_cache = dict(cache, k_pages=kp, v_pages=vp)
    kv_pos = paged_kv_positions(sl, n_slots)
    mask = paged_decode_attention_mask(kv_pos, sl)
    seq_sharded = (ctx.mesh is not None
                   and "model" in ctx.mesh.axis_names
                   and _axis_size(ctx.mesh, "model") > 1)
    if ctx.use_kernels and not seq_sharded:
        from repro.kernels.flash_decode_paged import flash_decode_paged
        out = flash_decode_paged(q, kp, vp, bt, mask,
                                 interpret=ctx.interpret).astype(q.dtype)
    else:
        # jnp oracle: gather each request's pages into contiguous K/V
        kf = kp[bt].reshape(r, n_slots, kvh, hd)
        vf = vp[bt].reshape(r, n_slots, kvh, hd)
        out = _masked_decode_attention(q, kf, vf, mask, h)
    return out, new_cache


def _paged_attention_prefill(ctx: Ctx, cfg: ArchConfig, q, k, v, cache):
    """Batched ragged admission prefill over a paged block-table cache.

    q/k/v: (R, S, ·, hd) — each row holds one admission's *suffix* (the
    prompt tokens after its shared prefix), already roped at the absolute
    positions ``seq_lens[r] + i`` (``seq_lens`` carries the per-request
    prefix offsets during an admission dispatch; ``prefill_lens`` the
    valid suffix lengths, 0 for idle slots).  The suffix K/V is scattered
    into each request's own pages first — padding and idle rows land on
    the engine's reserved scratch page (physical page 0) — then every
    suffix query attends causally over the request's full logical prefix:
    pages mapped from the prefix cache plus the suffix written by this
    same dispatch (admissions sharing a boundary therefore read each
    other's freshly computed prefix K/V in-graph, which is what makes a
    shared-prefix burst prefill-once).

    Oracle path: gather pages through the block table and run the same
    chunked mea_attention the contiguous prefill uses (per-request 2D
    query positions) — serial batch-1 prefill and this batched path
    reduce with identical math.  Kernel path (``ctx.use_kernels``):
    kernels/flash_prefill_ragged.py, block table + offsets/lens as
    scalar prefetch, mask semantics per
    :func:`ragged_prefill_attention_mask`.
    """
    assert not cfg.sliding_window, \
        "paged prefill supports linear caches only"
    kp, vp = cache["k_pages"], cache["v_pages"]
    bt, off = cache["block_tables"], cache["seq_lens"]
    lens = cache["prefill_lens"]
    _, ps, kvh, hd = kp.shape
    r, s, h, _ = q.shape
    blocks = bt.shape[1]
    n_slots = blocks * ps
    # scatter the suffix K/V at positions offset..offset+len-1; invalid
    # (padded / idle-slot) writes are routed to the scratch page 0
    pos = ragged_prefill_positions(off, s)
    valid = jnp.arange(s)[None] < lens[:, None]
    pos_c = jnp.minimum(pos, n_slots - 1)
    rows = jnp.arange(r)[:, None]
    pidx = jnp.where(valid, bt[rows, pos_c // ps], 0)
    slot = pos_c % ps
    kp = kp.at[pidx, slot].set(k.astype(kp.dtype))
    vp = vp.at[pidx, slot].set(v.astype(vp.dtype))
    new_cache = dict(cache, k_pages=kp, v_pages=vp)
    seq_sharded = (ctx.mesh is not None
                   and "model" in ctx.mesh.axis_names
                   and _axis_size(ctx.mesh, "model") > 1)
    if ctx.use_kernels and not seq_sharded:
        from repro.kernels import autotune
        from repro.kernels.flash_prefill_ragged import flash_prefill_ragged
        tile, _ = autotune.tile_readback(
            "flash_prefill_ragged",
            autotune.flash_prefill_ragged_problem(r, s, h, kvh, hd,
                                                  n_slots, ps, q.dtype))
        out = flash_prefill_ragged(q, kp, vp, bt, off, lens,
                                   interpret=ctx.interpret,
                                   block_q=tile["block_q"]).astype(q.dtype)
    else:
        # jnp oracle: gather each request's pages into contiguous K/V and
        # run the standard chunked-mea prefill with per-request positions
        kf = kp[bt].reshape(r, n_slots, kvh, hd)
        vf = vp[bt].reshape(r, n_slots, kvh, hd)
        out = mea_attention(q, _expand_kv(kf, h), _expand_kv(vf, h),
                            pos, jnp.arange(n_slots),
                            causal=True, chunk=cfg.attn_chunk,
                            bf16_operands=cfg.mea_bf16)
    return out, new_cache


def init_attention_cache(cfg: ArchConfig, batch: int, seq_len: int,
                         dtype=jnp.bfloat16):
    cache_len = seq_len if not cfg.sliding_window else min(
        seq_len, cfg.sliding_window)
    kv, hd = cfg.n_kv_heads, cfg.hd
    return (
        {"k": jnp.zeros((batch, cache_len, kv, hd), dtype),
         "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
         "pos": jnp.zeros((), jnp.int32)},
        {"k": ("batch", "cache_seq", "kv_heads", "head_dim"),
         "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
         "pos": ()},
    )


# =====================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# =====================================================================
def init_mla(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    qd = cfg.nope_head_dim + cfg.rope_head_dim
    ks = jax.random.split(key, 8)
    dt = cfg.pdt
    params = {}
    axes = {}
    if cfg.q_lora_rank:
        params["wq_a"] = dense_init(ks[0], d, cfg.q_lora_rank, dt)
        params["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, h * qd, dt)
        qn, _ = init_norm(cfg.norm, cfg.q_lora_rank, dt)
        params["q_a_norm"] = qn
        axes.update(wq_a=("embed", "q_lora"), wq_b=("q_lora", "heads"),
                    q_a_norm={k: ("q_lora",) for k in qn})
    else:
        params["wq"] = dense_init(ks[0], d, h * qd, dt)
        axes["wq"] = ("embed", "heads")
    params["wkv_a"] = dense_init(ks[2], d,
                                 cfg.kv_lora_rank + cfg.rope_head_dim, dt)
    kn, _ = init_norm(cfg.norm, cfg.kv_lora_rank, dt)
    params["kv_a_norm"] = kn
    params["wkv_b"] = dense_init(
        ks[3], cfg.kv_lora_rank,
        h * (cfg.nope_head_dim + cfg.v_head_dim), dt)
    params["wo"] = dense_init(ks[4], h * cfg.v_head_dim, d, dt)
    axes.update(wkv_a=("embed", "kv_lora"),
                kv_a_norm={k: ("kv_lora",) for k in kn},
                wkv_b=("kv_lora", "heads"), wo=("heads", "embed"))
    return params, axes


def mla_attention(ctx: Ctx, cfg: ArchConfig, p, x, positions,
                  cache: dict | None = None):
    """MLA with the compressed-KV cache (c_kv + k_rope only)."""
    b, s, d = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    if cfg.q_lora_rank:
        qa = linear(ctx, "attn/wq_a", x, p["wq_a"])
        qa = norm_apply(cfg.norm, p["q_a_norm"], qa)
        q = linear(ctx, "attn/wq_b", qa, p["wq_b"])
    else:
        q = linear(ctx, "attn/wq", x, p["wq"])
    q = q.reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(ctx, "attn/wkv_a", x, p["wkv_a"])
    ckv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    ckv = norm_apply(cfg.norm, p["kv_a_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    new_cache = cache
    if cache is not None and ctx.decode:
        pos = cache["pos"]
        ckv_all = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, pos, 0))
        new_cache = {"ckv": ckv_all, "krope": kr_all, "pos": pos + s}
        slot = jnp.arange(ckv_all.shape[1])
        kv_pos = jnp.where(slot <= pos, slot, 2**30)
        ckv_use, kr_use = ckv_all, kr_all
    else:
        kv_pos = positions
        ckv_use, kr_use = ckv, k_rope
        if cache is not None:
            ckv_all = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            kr_all = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope.astype(cache["krope"].dtype),
                (0, 0, 0))
            new_cache = {"ckv": ckv_all, "krope": kr_all,
                         "pos": jnp.asarray(s, jnp.int32)}

    # absorb: k_nope = ckv @ Wk_b, v = ckv @ Wv_b.  We keep the expanded
    # form (compute k/v from the compressed cache at attention time) —
    # memory stays O(kv_lora), compute is the standard MLA recompute.
    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, h, nd + vd)
    k_nope = jnp.einsum("bsc,chd->bshd", ckv_use.astype(jnp.float32),
                        wkv_b[..., :nd].astype(jnp.float32))
    v = jnp.einsum("bsc,chd->bshd", ckv_use.astype(jnp.float32),
                   wkv_b[..., nd:].astype(jnp.float32)).astype(x.dtype)
    k = jnp.concatenate(
        [k_nope.astype(x.dtype),
         jnp.broadcast_to(kr_use[:, :, None, :],
                          (*kr_use.shape[:2], h, rd)).astype(x.dtype)],
        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cache is not None and ctx.decode:
        scale = 1.0 / math.sqrt(nd + rd)
        sgl = jnp.einsum("bqhd,bkhd->bhqk",
                         qfull.astype(jnp.float32) * scale,
                         k.astype(jnp.float32))
        mask = kv_pos[None, None, None, :] < 2**30
        sgl = jnp.where(mask, sgl, NEG_INF)
        w = jax.nn.softmax(sgl, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)
                         ).astype(x.dtype)
    else:
        out = mea_attention(qfull, k, v, positions, kv_pos, causal=True,
                            chunk=cfg.attn_chunk,
                            bf16_operands=cfg.mea_bf16)
    y = linear(ctx, "attn/wo", out.reshape(b, s, h * vd), p["wo"])
    return y, new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, seq_len: int,
                   dtype=jnp.bfloat16):
    return (
        {"ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
         "krope": jnp.zeros((batch, seq_len, cfg.rope_head_dim), dtype),
         "pos": jnp.zeros((), jnp.int32)},
        {"ckv": ("batch", "cache_seq", "kv_lora"),
         "krope": ("batch", "cache_seq", None),
         "pos": ()},
    )


# =====================================================================
# Dense MLPs
# =====================================================================
def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.pdt
    if cfg.mlp == "glu":
        params = {"w_gate": dense_init(ks[0], d, f, dt),
                  "w_up": dense_init(ks[1], d, f, dt),
                  "w_down": dense_init(ks[2], f, d, dt,
                                       scale=1.0 / math.sqrt(f))}
        axes = {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
                "w_down": ("ffn", "embed")}
    else:
        params = {"w_up": dense_init(ks[0], d, f, dt),
                  "w_down": dense_init(ks[1], f, d, dt,
                                       scale=1.0 / math.sqrt(f))}
        axes = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
        if cfg.mlp_bias:
            params.update(b_up=jnp.zeros((f,), dt),
                          b_down=jnp.zeros((d,), dt))
            axes.update(b_up=("ffn",), b_down=("embed",))
    return params, axes


def mlp(ctx: Ctx, cfg: ArchConfig, p, x):
    if cfg.mlp == "glu":
        g = linear(ctx, "mlp/w_gate", x, p["w_gate"])
        u = linear(ctx, "mlp/w_up", x, p["w_up"])
        h = act_fn("silu")(g.astype(jnp.float32)).astype(x.dtype) * u
        return linear(ctx, "mlp/w_down", h, p["w_down"])
    u = linear(ctx, "mlp/w_up", x, p["w_up"], p.get("b_up"))
    h = act_fn("gelu")(u.astype(jnp.float32)).astype(x.dtype)
    return linear(ctx, "mlp/w_down", h, p["w_down"], p.get("b_down"))


# =====================================================================
# Mixture of Experts (expert-parallel via shard_map; DESIGN.md §5)
# =====================================================================
def init_moe(key, cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_expert, cfg.n_experts
    ks = jax.random.split(key, 6)
    dt = cfg.pdt

    def stack(k, din, dout, scale=None):
        kk = jax.random.split(k, e)
        return jnp.stack([dense_init(kk[i], din, dout, dt, scale)
                          for i in range(e)])

    params: dict[str, Any] = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": stack(ks[1], d, f),
        "w_up": stack(ks[2], d, f),
        "w_down": stack(ks[3], f, d, 1.0 / math.sqrt(f)),
    }
    axes: dict[str, Any] = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ffn"),
        "w_up": ("experts", "embed", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "embed"),
    }
    if cfg.n_shared_experts:
        sh, shax = init_mlp(ks[4], cfg, cfg.d_expert * cfg.n_shared_experts)
        params["shared"] = sh
        axes["shared"] = shax
    return params, axes


def _rank_in_expert(ids: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each token copy within its expert's queue."""
    nk = ids.shape[0]
    sort_idx = jnp.argsort(ids, stable=True)
    sorted_ids = ids[sort_idx]
    counts = jax.ops.segment_sum(jnp.ones((nk,), jnp.int32), ids,
                                 num_segments=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_ids]
    return jnp.zeros((nk,), jnp.int32).at[sort_idx].set(pos_sorted)


def _expert_ffn(ctx: Ctx, recv, wg, wu, wd, psum_axes=None):
    """(E,C,d) tokens through per-expert GLU FFN (E,d,f)/(E,f,d).

    ``psum_axes``: the f dim of the weights is a SHARD (FSDP 'partial'
    mode) — silu(g)*u is computed on the local f-slice and the down-proj
    partial sums are psum'd over those axes.  NOTE: exact only because GLU
    is elementwise in f; the psum crosses only the final contraction.
    """
    level = ctx.level_for("moe/experts")
    if level == INT8:
        def q3(w):  # per-expert, per-out-channel int8
            qw, sc = quantize_int8(w, axis=1)
            deq = qw.astype(jnp.float32) * sc
            if ctx.decode:   # no grads needed: use quantized values as-is
                return deq   # (TPU path: the Pallas int8 kernel)
            # training: straight-through — quantize forward, full-precision
            # gradient (quantize_int8's round has ZERO derivative
            # otherwise; see common._int8_mm_ste)
            wf = w.astype(jnp.float32)
            return wf + jax.lax.stop_gradient(deq - wf)
        wg, wu, wd = q3(wg), q3(wu), q3(wd)
    rf = recv.astype(jnp.bfloat16)
    g = jnp.einsum("ecd,edf->ecf", rf, wg.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", rf, wu.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(jnp.bfloat16)
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    if psum_axes is not None:
        y = jax.lax.psum(y, psum_axes)
    return y.astype(recv.dtype)


def moe_ffn(ctx: Ctx, cfg: ArchConfig, p, x):
    """Top-k routed MoE with explicit expert parallelism.

    Outside any mesh (CPU unit tests): dense reference (loop over experts).
    With a mesh: shard_map over the ``model`` axis — tokens are
    sequence-split across model ranks, routed, all-to-all'd to expert
    owners, processed, and combined back (DESIGN.md §5).
    """
    y_shared = 0.0
    if cfg.n_shared_experts:
        y_shared = mlp(ctx, cfg.replace(mlp="glu"), p["shared"], x)

    mesh = ctx.mesh
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and cfg.n_experts % _axis_size(mesh, "model") == 0)
    if use_ep:
        y = _moe_ep(ctx, cfg, p, x)
    else:
        y = _moe_dense_reference(ctx, cfg, p, x)
    return y + y_shared


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _router(cfg: ArchConfig, router_w, x2):
    logits = x2.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, eidx


def _moe_dense_reference(ctx: Ctx, cfg: ArchConfig, p, x):
    """O(E) dense reference — smoke-test scale only."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    gates, eidx = _router(cfg, p["router"], x2)
    onehot = jax.nn.one_hot(eidx, cfg.n_experts, dtype=jnp.float32)
    combine = jnp.einsum("nk,nke->ne", gates, onehot)       # (N,E)
    h_g = jnp.einsum("nd,edf->nef", x2.astype(jnp.float32),
                     p["w_gate"].astype(jnp.float32))
    h_u = jnp.einsum("nd,edf->nef", x2.astype(jnp.float32),
                     p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("nef,efd->ned", h, p["w_down"].astype(jnp.float32))
    y = jnp.einsum("ned,ne->nd", y_e, combine)
    return y.reshape(b, s, d).astype(x.dtype)


def _moe_ep(ctx: Ctx, cfg: ArchConfig, p, x):
    mesh = ctx.mesh
    tp = _axis_size(mesh, "model")
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // tp
    b, s, d = x.shape
    from jax.sharding import PartitionSpec as P
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bd = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    x_spec = P(bd, None, None)
    if ctx.fsdp_params and bd is not None:
        w_in_spec = P("model", None, bd)   # fsdp-shard f dim
        wd_spec = P("model", bd, None)
    else:
        w_in_spec = P("model", None, None)
        wd_spec = P("model", None, None)

    def ep_small_fn(xl, router_w, wg, wu, wd):
        """Few-token path (decode): routing is replicated across model
        ranks; each rank runs only its local experts and the outputs are
        psum-combined — no all-to-all, comm is one psum of (N, d)."""
        rank = jax.lax.axis_index("model")
        n_loc = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(n_loc, d)
        if ctx.fsdp_params and bd is not None:
            wg = jax.lax.all_gather(wg, bd, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, bd, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, bd, axis=1, tiled=True)
        gates, eidx = _router(cfg, router_w, x2)
        ids = eidx.reshape(-1)
        gflat = gates.reshape(-1)
        src = jnp.arange(n_loc * k, dtype=jnp.int32) // k
        cap = max(8, int(math.ceil(n_loc * k * cfg.capacity_factor / e)))
        pos = _rank_in_expert(ids, e)
        local = (ids >= rank * e_loc) & (ids < (rank + 1) * e_loc)
        keep = (pos < cap) & local
        slot = jnp.where(keep, (ids - rank * e_loc) * cap + pos,
                         e_loc * cap)
        disp = jnp.zeros((e_loc * cap + 1, d), x2.dtype).at[slot].set(
            x2[src] * keep[:, None].astype(x2.dtype))
        recv = disp[:e_loc * cap].reshape(e_loc, cap, d)
        y_e = _expert_ffn(ctx, recv, wg, wu, wd).reshape(e_loc * cap, d)
        y_e = jnp.concatenate([y_e, jnp.zeros((1, d), y_e.dtype)], 0)
        y_tok = y_e[slot] * (gflat * keep)[:, None].astype(y_e.dtype)
        ys = jax.ops.segment_sum(y_tok, src, num_segments=n_loc)
        ys = jax.lax.psum(ys, "model")
        return ys.reshape(xl.shape)

    def ep_fn(xl, router_w, wg, wu, wd):
        # xl: (B_loc, S, d) — replicated over model; take this rank's slice.
        rank = jax.lax.axis_index("model")
        n_loc = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(n_loc, d)
        n_slice = n_loc // tp
        xs = jax.lax.dynamic_slice(x2, (rank * n_slice, 0), (n_slice, d))
        if ctx.fsdp_params and bd is not None:
            # NOTE a "partial" variant (keep f-sharded weights, psum the
            # down-proj partials) was tried and REFUTED: with batch sharded
            # over the same (pod,data) axes, the psum mixes different data
            # ranks' tokens (EXPERIMENTS.md §Perf pair B).  Weight gather
            # it is; the gather payload is halved by int8 storage instead.
            wg = jax.lax.all_gather(wg, bd, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, bd, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, bd, axis=1, tiled=True)

        gates, eidx = _router(cfg, router_w, xs)
        ids = eidx.reshape(-1)                      # (n_slice*k,)
        gflat = gates.reshape(-1)
        src = jnp.arange(n_slice * k, dtype=jnp.int32) // k
        cap = max(8, int(math.ceil(n_slice * k * cfg.capacity_factor / e)))
        pos = _rank_in_expert(ids, e)
        keep = pos < cap
        slot = jnp.where(keep, ids * cap + pos, e * cap)
        disp = jnp.zeros((e * cap + 1, d), xs.dtype).at[slot].set(
            xs[src] * keep[:, None].astype(xs.dtype))
        disp = disp[:e * cap].reshape(tp, e_loc, cap, d)
        recv = jax.lax.all_to_all(disp, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, tp * cap, d)
        recv = checkpoint_name(recv, "moe_recv")
        y_e = _expert_ffn(ctx, recv, wg, wu, wd)
        y_e = y_e.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y_e, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(e * cap, d)
        back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], 0)
        y_tok = back[slot] * (gflat * keep)[:, None].astype(back.dtype)
        ys = jax.ops.segment_sum(y_tok.astype(jnp.float32), src,
                                 num_segments=n_slice)
        # cast before the cross-model gather: halves the largest per-layer
        # activation collective (f32 -> activation dtype)
        y_full = jax.lax.all_gather(ys.astype(xl.dtype), "model", axis=0,
                                    tiled=True)
        return y_full.reshape(xl.shape)

    # few tokens per data shard (decode): the token-slice/all-to-all path
    # can't split the tokens across model ranks — use the local-expert+psum
    # path instead.
    dp = 1
    for a in dp_axes:
        dp *= _axis_size(mesh, a)
    n_loc_static = (b // max(1, dp)) * s
    body = ep_fn if (n_loc_static % tp == 0 and n_loc_static >= tp) \
        else ep_small_fn
    fn = _shard_map_unchecked(body, mesh=mesh,
                              in_specs=(x_spec, P(None, None), w_in_spec,
                                        w_in_spec, wd_spec),
                              out_specs=x_spec)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_aux_loss(cfg: ArchConfig, router_w, x) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss."""
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    logits = x2.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(probs, cfg.top_k)
    onehot = jax.nn.one_hot(eidx, cfg.n_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
