"""Model protocol: one uniform handle over the whole zoo.

``build_model(cfg, mesh=...)`` returns a :class:`LMModel` (decoder-only
families + enc-dec audio) exposing:

- ``init(key)`` / ``abstract_params()`` (eval_shape — no allocation)
- ``param_axes()``: logical-axis pytree parallel to params
- ``loss(params, batch, ctx)``: LM cross-entropy (+ MoE aux loss)
- ``prefill(params, batch, ctx)`` / ``decode_step(params, cache, tokens)``
- ``init_cache`` / ``abstract_cache`` + cache axes
- ``input_specs(shape)``: ShapeDtypeStruct stand-ins for the dry-run

The paper's CNN benchmarks (jet_dnn / vgg7 / resnet9) use the lighter
functional interface in models/cnn.py — the O-tasks accept either through
``repro.tasks.model_gen``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T
from repro.models.common import Ctx
from repro.quant.policy import PrecisionPolicy

# MoE routers and SSM gate/Δ projections exempt from quant/prune by default
DEFAULT_EXEMPT = ["*router*", "*w_if*", "*dt_*", "*A_log*", "*gate_logit*"]


def _xent(cfg, logits, labels):
    """Cross-entropy with vocab-padding masking and optional seq chunking
    (cfg.loss_chunk tokens at a time — bounds the fp32 softmax live set)."""
    v_real = cfg.vocab_size
    v = logits.shape[-1]

    def chunk_nll(lg, lb):
        lf = lg.astype(jnp.float32)
        if v > v_real:  # mask padded vocab columns exactly
            col = jnp.arange(v)
            lf = jnp.where(col[None, None] < v_real, lf, -1e30)
        logz = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lb[..., None], axis=-1)[..., 0]
        return logz - gold, logz

    c = cfg.loss_chunk
    if not c or logits.shape[1] <= c or logits.shape[1] % c:
        return chunk_nll(logits, labels)
    n = logits.shape[1] // c
    lg = logits.reshape(logits.shape[0], n, c, v).transpose(1, 0, 2, 3)
    lb = labels.reshape(labels.shape[0], n, c).transpose(1, 0, 2)
    (nll, logz) = jax.lax.map(lambda t: chunk_nll(*t), (lg, lb))
    return (nll.transpose(1, 0, 2).reshape(labels.shape),
            logz.transpose(1, 0, 2).reshape(labels.shape))


@dataclasses.dataclass
class LMModel:
    cfg: ArchConfig
    mesh: Any = None
    policy: PrecisionPolicy | None = None
    use_kernels: bool = False
    interpret: bool = False
    fsdp_params: bool = False
    moe_fsdp_mode: str = "gather"

    # ----------------------------------------------------------- context
    def ctx(self, decode: bool = False) -> Ctx:
        return Ctx(policy=self.policy, mesh=self.mesh,
                   use_kernels=self.use_kernels, interpret=self.interpret,
                   remat=self.cfg.remat, decode=decode,
                   fsdp_params=self.fsdp_params,
                   moe_fsdp_mode=self.moe_fsdp_mode)

    # -------------------------------------------------------------- init
    def init(self, key):
        if self.cfg.enc_dec:
            return T.init_encdec(key, self.cfg)[0]
        return T.init_lm(key, self.cfg)[0]

    @functools.cached_property
    def _abstract(self):
        """(abstract params, axes) with zero device allocation.

        The init runs under eval_shape; the (static, python-built) axes
        tree is captured through a side channel during tracing.
        """
        init = T.init_encdec if self.cfg.enc_dec else T.init_lm
        box = {}

        def f(k):
            p, a = init(k, self.cfg)
            box["axes"] = a
            return p

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["axes"]

    @property
    def _axes(self):
        return self._abstract[1]

    def param_axes(self):
        return self._axes

    def abstract_params(self):
        return self._abstract[0]

    # -------------------------------------------------------------- loss
    def loss(self, params, batch, ctx: Ctx | None = None):
        """Mean LM cross-entropy over the batch (+ 0.01 * MoE aux loss)."""
        ctx = ctx or self.ctx()
        cfg = self.cfg
        if cfg.enc_dec:
            enc_out = T.encdec_encode(ctx, cfg, params, batch["frames"])
            logits, _ = T.encdec_decode(ctx, cfg, params, batch["tokens"],
                                        enc_out=enc_out)
        else:
            inp = batch.get("embeds", batch["tokens"])
            logits, _ = T.lm_apply(ctx, cfg, params, inp)
        labels = batch["labels"]
        nll, logz = _xent(cfg, logits, labels)
        loss = jnp.mean(nll)
        # z-loss for stability at scale
        loss = loss + 1e-4 * jnp.mean(logz ** 2)
        return loss, {"nll": jnp.mean(nll),
                      "ppl_proxy": jnp.exp(jnp.minimum(jnp.mean(nll), 20.0))}

    # ----------------------------------------------------------- serving
    def prefill(self, params, batch, cache=None, ctx: Ctx | None = None):
        ctx = ctx or self.ctx(decode=False)
        cfg = self.cfg
        if cfg.enc_dec:
            b = batch["tokens"].shape[0]
            if cache is None:
                seq = self._cache_len()
                dtype = jnp.bfloat16
            else:
                seq = cache["self"]["k"].shape[2]
                dtype = cache["cross_k"].dtype
            cache, _ = T.init_encdec_cache(ctx, cfg, params, b, seq,
                                           frames=batch["frames"],
                                           dtype=dtype)
            logits, cache = T.encdec_decode(ctx, cfg, params,
                                            batch["tokens"], cache=cache)
            return logits, cache
        if cache is None:
            cache, _ = self.init_cache(batch["tokens"].shape[0],
                                       batch["tokens"].shape[1])
        inp = batch.get("embeds", batch["tokens"])
        return T.lm_apply(ctx, cfg, params, inp, cache=cache)

    def decode_step(self, params, cache, tokens, ctx: Ctx | None = None):
        """One-token decode.  tokens: (B,1) int32."""
        ctx = ctx or self.ctx(decode=True)
        cfg = self.cfg
        if cfg.enc_dec:
            return T.encdec_decode(ctx, cfg, params, tokens, cache=cache)
        return T.lm_apply(ctx, cfg, params, tokens, cache=cache)

    # -------------------------------------------------------------- cache
    def _cache_len(self, seq_len: int | None = None) -> int:
        return seq_len if seq_len is not None else 4096

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.enc_dec:
            return self._encdec_empty_cache(batch, seq_len, dtype)
        return T.init_lm_cache(cfg, batch, seq_len, dtype)

    def _encdec_empty_cache(self, batch, seq_len, dtype):
        cfg = self.cfg
        from repro.models import layers as Lay
        enc_cfg = cfg.replace(use_rope=False, sliding_window=0)
        sc, sa = Lay.init_attention_cache(enc_cfg, batch, seq_len, dtype)
        n = cfg.n_layers
        scs = jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape),
                           sc)
        sas = jax.tree.map(lambda ax: ("layers",) + tuple(ax), sa,
                           is_leaf=lambda x: isinstance(x, tuple))
        kvshape = (n, batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
        cache = {"self": scs, "cross_k": jnp.zeros(kvshape, dtype),
                 "cross_v": jnp.zeros(kvshape, dtype)}
        axes = {"self": sas,
                "cross_k": ("layers", "batch", "frames", "kv_heads",
                            "head_dim"),
                "cross_v": ("layers", "batch", "frames", "kv_heads",
                            "head_dim")}
        return cache, axes

    def abstract_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        box = {}

        def f():
            c, a = self.init_cache(batch, seq_len, dtype)
            box["axes"] = a
            return c

        shapes = jax.eval_shape(f)
        return shapes, box["axes"]

    def cache_axes(self, batch: int, seq_len: int):
        return self.abstract_cache(batch, seq_len)[1]

    # -------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeSpec) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (the dry-run contract; no device allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        if shape.is_decode:
            toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            return {"tokens": toks}
        s = shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.enc_dec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            pass  # early fusion: VQ image tokens share the token stream
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs


def build_model(cfg: ArchConfig, mesh=None, policy: PrecisionPolicy = None,
                **kw) -> LMModel:
    if policy is None:
        policy = PrecisionPolicy(default="bf16", exempt=DEFAULT_EXEMPT)
    return LMModel(cfg=cfg, mesh=mesh, policy=policy, **kw)
