"""Shared model-substrate utilities: apply context, init helpers, norms.

Conventions used across the whole model zoo:

- Parameters are nested dicts of jnp arrays.  Every ``init_*`` returns
  ``(params, axes)`` where ``axes`` is a parallel nested dict whose leaves
  are tuples of *logical axis names* (see parallel/sharding.py).
- Weights are stored ``(in_features, out_features)``; contraction is always
  on axis 0 of the weight.
- ``Ctx`` carries cross-cutting state through apply functions: the
  quantization policy (the QUANTIZATION O-task's output), the mesh (for
  shard_map-based expert parallelism), and kernel dispatch flags.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.policy import (BF16, FP32, FP8, INT8, PrecisionPolicy,
                                quantize_int8)


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Apply-time context threaded through every layer."""
    policy: PrecisionPolicy | None = None
    mesh: Any = None                 # jax.sharding.Mesh or None
    use_kernels: bool = False        # Pallas kernels (TPU target)
    interpret: bool = False          # Pallas interpret mode (CPU tests)
    remat: str = "none"              # none | dots | full
    decode: bool = False
    fsdp_params: bool = False        # FSDP-shard MoE expert weights
    moe_fsdp_mode: str = "gather"    # gather weights | "partial": compute
    # on f-sharded weights and psum activations (12x less volume when
    # C*d << weight bytes — §Perf pair B)

    def level_for(self, name: str) -> str:
        if self.policy is None:
            return BF16
        return self.policy.level_for(name)


DEFAULT_CTX = Ctx()


# ------------------------------------------------------------------ init
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ------------------------------------------------------------- linear op
def linear(ctx: Ctx, name: str, x: jnp.ndarray, w: jnp.ndarray,
           b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Policy-dispatched linear layer: ``x @ w + b``.

    The precision level for ``name`` decides the executed kernel — this is
    the cross-stage hook where the QUANTIZATION O-task's per-layer policy is
    "instrumented into the kernel" (paper §V-B, DESIGN.md §2).
    """
    level = ctx.level_for(name)
    out_dtype = x.dtype
    if level == INT8:
        y = _int8_matmul(ctx, x, w)
    elif level == FP8:
        # weight-only fp8 (e4m3) storage; bf16 MACs.
        w8 = w.astype(jnp.dtype("float8_e4m3fn")).astype(jnp.bfloat16)
        y = jnp.matmul(x.astype(jnp.bfloat16), w8,
                       preferred_element_type=jnp.float32)
    elif level == FP32:
        y = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    else:  # BF16
        y = jnp.matmul(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    y = y.astype(out_dtype)
    if b is not None:
        y = y + b.astype(out_dtype)
    return y


@jax.custom_vjp
def _int8_mm_ste(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Dynamic-activation int8 x int8 matmul, int32 accumulation.

    custom_vjp: the FORWARD runs real int8 dots (MXU int8 path — this is
    what the dry-run/roofline sees); the BACKWARD is the straight-through
    estimator (grads as if the matmul were full-precision), so int8
    policies train correctly (QAT semantics).  Without this, jnp.round's
    zero derivative silently kills the backward pass — found the hard way
    in §Perf pair A.
    """
    wq, wscale = quantize_int8(w, axis=0)           # (in,out), (1,out)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    xscale = jnp.maximum(absmax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) / xscale), -127, 127
                  ).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, wq, (((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xscale * wscale.reshape(
        (1,) * (acc.ndim - 1) + (-1,))


def _int8_mm_fwd(x, w):
    return _int8_mm_ste(x, w), (x, w)


def _int8_mm_bwd(res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    gx = jnp.matmul(gf, w.astype(jnp.float32).T).astype(x.dtype)
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    g2 = gf.reshape(-1, gf.shape[-1])
    gw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return gx, gw


_int8_mm_ste.defvjp(_int8_mm_fwd, _int8_mm_bwd)


def _int8_matmul(ctx: Ctx, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    if ctx.use_kernels and x.ndim >= 2 and w.ndim == 2:
        from repro.kernels import ops as kops
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y2 = kops.quant_matmul(x2, w, interpret=ctx.interpret)
        return y2.reshape(*lead, w.shape[-1]).astype(x.dtype)
    return _int8_mm_ste(x, w)


# ------------------------------------------------------------------ norms
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_apply(kind: str, params, x: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


def init_norm(kind: str, d: int, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" \
        else init_layernorm(d, dtype)


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoid_positions(n: int, d: int) -> jnp.ndarray:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ------------------------------------------------------------- activations
def act_fn(kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[kind]


def shard_hidden(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    """Constraint: activations sharded on batch over (pod,data)."""
    if ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in ctx.mesh.axis_names)
    if not axes:
        return x
    spec = P(axes if len(axes) > 1 else axes[0],
             *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))
