"""Model assembly: decoder-only LMs (dense / MoE / MLA / SSM / hybrid) and
the whisper-style encoder-decoder — all scan-over-layers with stacked params.

Block taxonomy (cfg.family):
- dense | vlm : [attn_norm → attn → +res, mlp_norm → mlp → +res] × L
- moe         : same with MoE FFN (and MLA attention when cfg.use_mla)
- ssm (xLSTM) : [norm → mLSTM → +res, norm → sLSTM(+internal FFN) → +res] × L/2
- hybrid      : segments of `hybrid_period` Mamba2 blocks followed by ONE
                weight-shared attention+MLP block (zamba2)
- audio       : whisper enc-dec; encoder over precomputed frame embeddings
                (conv frontend stubbed per spec), decoder self+cross attn

Caches mirror the block structure, stacked along the layer axis so decode
scans over (params, cache) together.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.common import (Ctx, dense_init, embed_init, init_norm,
                                 linear, norm_apply, shard_hidden,
                                 sinusoid_positions)


# --------------------------------------------------------------- helpers
def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots+moe":
        # save dot outputs AND the MoE all-to-all results (tagged
        # "moe_recv" in layers._moe_ep) so the backward pass does not
        # re-run the expensive dispatch collectives (§Perf pair B)
        pol = jax.checkpoint_policies.save_from_both_policies(
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            jax.checkpoint_policies.save_only_these_names("moe_recv"))
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _stack_init(key, n: int, init_fn):
    """vmap an init over n layer keys; returns (stacked params, axes)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


# ================================================================ blocks
def init_lm_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    ax: dict[str, Any] = {}
    p["attn_norm"], ax["attn_norm"] = init_norm(cfg.norm, cfg.d_model,
                                                cfg.pdt)
    if cfg.use_mla:
        p["attn"], ax["attn"] = L.init_mla(ks[0], cfg)
    else:
        p["attn"], ax["attn"] = L.init_attention(ks[0], cfg)
    p["mlp_norm"], ax["mlp_norm"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    if cfg.is_moe:
        p["moe"], ax["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["mlp"], ax["mlp"] = L.init_mlp(ks[1], cfg)
    return p, ax


def lm_block_apply(ctx: Ctx, cfg: ArchConfig, p, x, positions, cache):
    h = norm_apply(cfg.norm, p["attn_norm"], x)
    if cfg.use_mla:
        a, cache = L.mla_attention(ctx, cfg, p["attn"], h, positions, cache)
    else:
        a, cache = L.attention(ctx, cfg, p["attn"], h, positions, cache)
    x = x + a
    h = norm_apply(cfg.norm, p["mlp_norm"], x)
    if cfg.is_moe:
        f = L.moe_ffn(ctx, cfg, p["moe"], h)
    else:
        f = L.mlp(ctx, cfg, p["mlp"], h)
    x = x + f
    return shard_hidden(ctx, x), cache


def init_xlstm_block(key, cfg: ArchConfig):
    """One xLSTM 'double block' = mLSTM block + sLSTM block."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, ax = {}, {}
    p["norm_m"], ax["norm_m"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    p["mlstm"], ax["mlstm"] = S.init_mlstm(k1, cfg)
    p["norm_s"], ax["norm_s"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    p["slstm"], ax["slstm"] = S.init_slstm(k2, cfg)
    return p, ax


def xlstm_block_apply(ctx: Ctx, cfg: ArchConfig, p, x, positions, cache):
    mc = cache["mlstm"] if cache is not None else None
    sc = cache["slstm"] if cache is not None else None
    y, mc = S.mlstm_apply(ctx, cfg, p["mlstm"],
                          norm_apply(cfg.norm, p["norm_m"], x), mc)
    x = x + y
    y, sc = S.slstm_apply(ctx, cfg, p["slstm"],
                          norm_apply(cfg.norm, p["norm_s"], x), sc)
    x = x + y
    new_cache = None if cache is None else {"mlstm": mc, "slstm": sc}
    return shard_hidden(ctx, x), new_cache


def init_mamba_block(key, cfg: ArchConfig):
    p, ax = {}, {}
    p["norm"], ax["norm"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    p["mixer"], ax["mixer"] = S.init_mamba2(key, cfg)
    return p, ax


def mamba_block_apply(ctx: Ctx, cfg: ArchConfig, p, x, positions, cache):
    y, cache = S.mamba2_apply(ctx, cfg, p["mixer"],
                              norm_apply(cfg.norm, p["norm"], x), cache)
    return shard_hidden(ctx, x + y), cache


def init_shared_attn_block(key, cfg: ArchConfig):
    """zamba2's weight-shared attention+MLP block."""
    k1, k2 = jax.random.split(key)
    p, ax = {}, {}
    p["attn_norm"], ax["attn_norm"] = init_norm(cfg.norm, cfg.d_model,
                                                cfg.pdt)
    p["attn"], ax["attn"] = L.init_attention(k1, cfg)
    p["mlp_norm"], ax["mlp_norm"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    p["mlp"], ax["mlp"] = L.init_mlp(k2, cfg)
    return p, ax


# =============================================================== LM model
def padded_vocab(cfg: ArchConfig) -> int:
    m = cfg.pad_vocab_to_multiple
    if not m:
        return cfg.vocab_size
    return -(-cfg.vocab_size // m) * m


def init_lm(key, cfg: ArchConfig):
    """Any decoder-only family.  Returns (params, axes)."""
    ks = jax.random.split(key, 6)
    vp = padded_vocab(cfg)
    p: dict[str, Any] = {"embed": embed_init(ks[0], vp, cfg.d_model,
                                             cfg.pdt)}
    ax: dict[str, Any] = {"embed": ("vocab", "embed")}

    if cfg.family == "ssm":          # xLSTM: pairs of (mLSTM, sLSTM)
        n_pairs = cfg.n_layers // 2
        p["blocks"], ax["blocks"] = _stack_init(
            ks[1], n_pairs, lambda k: init_xlstm_block(k, cfg))
    elif cfg.family == "hybrid":     # zamba2
        p["blocks"], ax["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_mamba_block(k, cfg))
        p["shared"], ax["shared"] = init_shared_attn_block(ks[2], cfg)
    else:                            # dense / moe / vlm
        p["blocks"], ax["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_lm_block(k, cfg))

    p["final_norm"], ax["final_norm"] = init_norm(cfg.norm, cfg.d_model,
                                                  cfg.pdt)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[3], cfg.d_model, vp, cfg.pdt)
        ax["lm_head"] = ("embed", "vocab")
    return p, ax


def _n_scan_blocks(cfg: ArchConfig) -> int:
    return cfg.n_layers // 2 if cfg.family == "ssm" else cfg.n_layers


def _block_apply_fn(cfg: ArchConfig):
    return {"ssm": xlstm_block_apply, "hybrid": mamba_block_apply}.get(
        cfg.family, lm_block_apply)


def lm_apply(ctx: Ctx, cfg: ArchConfig, params, tokens, positions=None,
             cache=None):
    """tokens: int32 (B,S) — or float (B,S,D) pre-embedded (vlm/audio stubs).

    Returns (logits, new_cache).
    """
    if tokens.ndim == 2:
        x = params["embed"][tokens].astype(cfg.adt)
    else:
        x = tokens.astype(cfg.adt)
    b, s = x.shape[:2]
    if positions is None:
        if cache is not None and ctx.decode:
            if "block_tables" in cache:
                # paged cache (one sentinel key for the whole-model dict,
                # matching the scan_cache branch below; the per-layer dict
                # is detected by "k_pages" in layers.attention): ragged
                # batch, per-request positions.  The serving engine owns
                # the seq_lens increment (it knows which slots are
                # active); lm_apply only reads them.  During a batched
                # admission prefill seq_lens carries the shared-prefix
                # offsets, so the same helper positions both paths.
                positions = L.ragged_prefill_positions(cache["seq_lens"],
                                                       s)
            else:
                pos0 = _cache_pos(cfg, cache)
                positions = pos0 + jnp.arange(s)
        else:
            positions = jnp.arange(s)
    x = shard_hidden(ctx, x)

    block_fn = _block_apply_fn(cfg)

    if cfg.family == "hybrid":
        x, new_cache = _hybrid_stack(ctx, cfg, params, x, positions, cache)
    else:
        paged = cache is not None and "block_tables" in cache

        def body(xcarry, xs):
            lp, lc = xs
            if paged:
                # block tables / seq_lens are batch state shared by every
                # layer — injected here instead of stacked per layer.
                # prefill_lens (per-request valid suffix lengths) rides
                # along only during a batched ragged admission prefill
                # dispatch; its presence is what routes layers.attention
                # to the ragged-prefill branch.
                lc = dict(lc, block_tables=cache["block_tables"],
                          seq_lens=cache["seq_lens"])
                if "prefill_lens" in cache:
                    lc["prefill_lens"] = cache["prefill_lens"]
            y, nc = block_fn(ctx, cfg, lp, xcarry, positions, lc)
            if paged:
                nc = {"k_pages": nc["k_pages"], "v_pages": nc["v_pages"]}
            return y, nc

        body = _remat(cfg, body)
        scan_cache = cache["blocks"] if (cache is not None
                                         and (cfg.family == "ssm" or paged)
                                         ) else cache
        if cfg.scan_layers:
            if cache is None:
                x, new_scan_cache = jax.lax.scan(
                    lambda c, lp: (body(c, (lp, None))[0], None),
                    x, params["blocks"])
            else:
                x, new_scan_cache = jax.lax.scan(
                    body, x, (params["blocks"], scan_cache))
        else:  # unrolled python loop (cost-analysis probes; see dryrun.py)
            nb = _n_scan_blocks(cfg)
            outs = []
            for i in range(nb):
                lp = jax.tree.map(lambda t: t[i], params["blocks"])
                lc = None if cache is None else jax.tree.map(
                    lambda t: t[i], scan_cache)
                x, nc = body(x, (lp, lc))
                outs.append(nc)
            new_scan_cache = None if cache is None else jax.tree.map(
                lambda *ts: jnp.stack(ts), *outs)
        if cache is None:
            new_cache = None
        elif cfg.family == "ssm":
            new_cache = {"blocks": new_scan_cache, "pos": cache["pos"] + s}
        elif paged:
            new_cache = {"blocks": new_scan_cache,
                         "block_tables": cache["block_tables"],
                         "seq_lens": cache["seq_lens"]}
        else:
            new_cache = new_scan_cache

    x = norm_apply(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = linear(ctx, "lm_head", x, head)
    return logits, new_cache


def _cache_pos(cfg: ArchConfig, cache):
    """Current absolute position from any cache leaf named 'pos'."""
    if cfg.family == "ssm":
        return cache["pos"]
    if cfg.family == "hybrid":
        return cache["shared"]["pos"][0]
    return cache["pos"][0]


def _hybrid_stack(ctx: Ctx, cfg: ArchConfig, params, x, positions, cache):
    period = cfg.hybrid_period
    n_seg = cfg.n_layers // period
    shared = params["shared"]

    def seg_reshape(t):
        return t.reshape(n_seg, period, *t.shape[1:])

    mamba_params = jax.tree.map(seg_reshape, params["blocks"])

    def shared_apply(x, sc):
        h = norm_apply(cfg.norm, shared["attn_norm"], x)
        a, sc = L.attention(ctx, cfg, shared["attn"], h, positions, sc)
        x = x + a
        h = norm_apply(cfg.norm, shared["mlp_norm"], x)
        x = x + L.mlp(ctx, cfg, shared["mlp"], h)
        return shard_hidden(ctx, x), sc

    def inner(x, xs):
        lp, lc = xs
        return mamba_block_apply(ctx, cfg, lp, x, positions, lc)

    inner = _remat(cfg, inner)

    def outer(x, xs):
        seg_params, seg_cache, shared_cache = xs
        if not cfg.scan_layers:
            outs = []
            for j in range(period):
                lp = jax.tree.map(lambda t: t[j], seg_params)
                lc = None if seg_cache is None else jax.tree.map(
                    lambda t: t[j], seg_cache)
                x, nc = inner(x, (lp, lc))
                outs.append(nc)
            new_seg_cache = None if seg_cache is None else jax.tree.map(
                lambda *ts: jnp.stack(ts), *outs)
        elif seg_cache is None:
            x, _ = jax.lax.scan(
                lambda c, lp: (inner(c, (lp, None))[0], None),
                x, seg_params)
            new_seg_cache = None
        else:
            x, new_seg_cache = jax.lax.scan(inner, x,
                                            (seg_params, seg_cache))
        x, new_shared_cache = shared_apply(x, shared_cache)
        return x, (new_seg_cache, new_shared_cache)

    if not cfg.scan_layers:  # unrolled (cost-analysis probes)
        mamba_cache = None if cache is None else jax.tree.map(
            seg_reshape, cache["mamba"])
        new_m, new_s = [], []
        for i in range(n_seg):
            seg_p = jax.tree.map(lambda t: t[i], mamba_params)
            seg_c = None if cache is None else jax.tree.map(
                lambda t: t[i], mamba_cache)
            sh_c = None if cache is None else jax.tree.map(
                lambda t: t[i], cache["shared"])
            x, (nm, ns) = outer(x, (seg_p, seg_c, sh_c))
            new_m.append(nm)
            new_s.append(ns)
        if cache is None:
            return x, None
        new_mamba = jax.tree.map(lambda *ts: jnp.stack(ts), *new_m)
        new_shared = jax.tree.map(lambda *ts: jnp.stack(ts), *new_s)
        new_mamba = jax.tree.map(lambda t: t.reshape(-1, *t.shape[2:]),
                                 new_mamba)
        return x, {"mamba": new_mamba, "shared": new_shared}

    if cache is None:
        def outer_nc(x, seg_params):
            y, _ = outer(x, (seg_params, None, None))
            return y, None
        x, _ = jax.lax.scan(outer_nc, x, mamba_params)
        return x, None

    mamba_cache = jax.tree.map(seg_reshape, cache["mamba"])
    x, (new_mamba, new_shared) = jax.lax.scan(
        outer, x, (mamba_params, mamba_cache, cache["shared"]))
    new_mamba = jax.tree.map(lambda t: t.reshape(-1, *t.shape[2:]),
                             new_mamba)
    return x, {"mamba": new_mamba, "shared": new_shared}


# ----------------------------------------------------------------- cache
def init_lm_cache(cfg: ArchConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16):
    """Stacked decode cache + logical axes for the whole model."""
    def stack(n, c, a):
        cs = jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape), c)
        axs = jax.tree.map(lambda ax: ("layers",) + tuple(ax), a,
                           is_leaf=lambda x: isinstance(x, tuple))
        return cs, axs

    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        mc, ma = S.init_mlstm_state(cfg, batch)
        sc, sa = S.init_slstm_state(cfg, batch)
        c = {"mlstm": mc, "slstm": sc}
        a = {"mlstm": ma, "slstm": sa}
        cs, axs = stack(n_pairs, c, a)
        return ({"blocks": cs, "pos": jnp.zeros((), jnp.int32)},
                {"blocks": axs, "pos": ()})
    if cfg.family == "hybrid":
        mc, ma = S.init_mamba2_state(cfg, batch)
        mcs, maxs = stack(cfg.n_layers, mc, ma)
        n_seg = cfg.n_layers // cfg.hybrid_period
        ac, aa = L.init_attention_cache(cfg, batch, seq_len, dtype)
        acs, aaxs = stack(n_seg, ac, aa)
        return ({"mamba": mcs, "shared": acs},
                {"mamba": maxs, "shared": aaxs})
    if cfg.use_mla:
        c, a = L.init_mla_cache(cfg, batch, seq_len, dtype)
    else:
        c, a = L.init_attention_cache(cfg, batch, seq_len, dtype)
    return stack(cfg.n_layers, c, a)


# ======================================================== whisper enc-dec
def init_encdec(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    enc_cfg = cfg.replace(use_rope=False, sliding_window=0)
    p: dict[str, Any] = {}
    ax: dict[str, Any] = {}

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        bp, bax = {}, {}
        bp["attn_norm"], bax["attn_norm"] = init_norm(cfg.norm, cfg.d_model,
                                                      cfg.pdt)
        bp["attn"], bax["attn"] = L.init_attention(k1, enc_cfg)
        bp["mlp_norm"], bax["mlp_norm"] = init_norm(cfg.norm, cfg.d_model,
                                                    cfg.pdt)
        bp["mlp"], bax["mlp"] = L.init_mlp(k2, cfg)
        return bp, bax

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        bp, bax = enc_block(k)
        cp, cax = L.init_attention(k3, enc_cfg)
        bp["cross_norm"], bax["cross_norm"] = init_norm(cfg.norm,
                                                        cfg.d_model, cfg.pdt)
        bp["cross"], bax["cross"] = cp, cax
        return bp, bax

    p["enc_blocks"], ax["enc_blocks"] = _stack_init(ks[0], cfg.n_enc_layers,
                                                    enc_block)
    p["dec_blocks"], ax["dec_blocks"] = _stack_init(ks[1], cfg.n_layers,
                                                    dec_block)
    p["embed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, cfg.pdt)
    ax["embed"] = ("vocab", "embed")
    p["enc_norm"], ax["enc_norm"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    p["dec_norm"], ax["dec_norm"] = init_norm(cfg.norm, cfg.d_model, cfg.pdt)
    return p, ax


def encdec_encode(ctx: Ctx, cfg: ArchConfig, params, frames):
    """frames: (B, n_frames, d) precomputed conv-frontend embeddings."""
    enc_cfg = cfg.replace(use_rope=False, sliding_window=0)
    b, s, d = frames.shape
    x = frames.astype(cfg.adt) + sinusoid_positions(s, d).astype(cfg.adt)
    positions = jnp.arange(s)
    x = shard_hidden(ctx, x)

    def body(xc, lp):
        h = norm_apply(cfg.norm, lp["attn_norm"], xc)
        a, _ = L.attention(ctx, enc_cfg, lp["attn"], h, positions,
                           causal=False)
        xc = xc + a
        h = norm_apply(cfg.norm, lp["mlp_norm"], xc)
        xc = xc + L.mlp(ctx, cfg, lp["mlp"], h)
        return shard_hidden(ctx, xc), None

    body = _remat(cfg, body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda t: t[i], params["enc_blocks"])
            x, _ = body(x, lp)
    return norm_apply(cfg.norm, params["enc_norm"], x)


def encdec_decode(ctx: Ctx, cfg: ArchConfig, params, tokens, enc_out=None,
                  cache=None):
    """Decoder pass.  enc_out (B,F,d) for prefill; cache holds cross K/V
    after prefill so decode never re-touches the encoder."""
    enc_cfg = cfg.replace(use_rope=False, sliding_window=0)
    x = params["embed"][tokens].astype(cfg.adt)
    b, s = tokens.shape
    if cache is not None and ctx.decode:
        pos0 = cache["self"]["pos"][0]
        positions = pos0 + jnp.arange(s)
    else:
        positions = jnp.arange(s)
    x = x + sinusoid_positions(int(cfg.n_frames * 32),
                               cfg.d_model)[positions].astype(cfg.adt)
    x = shard_hidden(ctx, x)
    frame_pos = jnp.arange(cfg.n_frames)

    def body(xc, xs):
        lp, sc, ck, cv = xs
        h = norm_apply(cfg.norm, lp["attn_norm"], xc)
        a, sc = L.attention(ctx, enc_cfg, lp["attn"], h, positions, sc)
        xc = xc + a
        h = norm_apply(cfg.norm, lp["cross_norm"], xc)
        a, _ = L.attention(ctx, enc_cfg, lp["cross"], h, positions,
                           kv_override=(ck, cv, frame_pos))
        xc = xc + a
        h = norm_apply(cfg.norm, lp["mlp_norm"], xc)
        xc = xc + L.mlp(ctx, cfg, lp["mlp"], h)
        return shard_hidden(ctx, xc), sc

    body = _remat(cfg, body)

    if cache is None:
        # compute cross k/v on the fly from enc_out
        def body_nc(xc, lp):
            kq = L._qkv(ctx, enc_cfg, lp["cross"], enc_out)
            y, _ = body(xc, (lp, None, kq[1], kq[2]))
            return y, None
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body_nc, x, params["dec_blocks"])
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda t: t[i], params["dec_blocks"])
                x, _ = body_nc(x, lp)
        new_cache = None
    elif cfg.scan_layers:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["self"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, self=new_self)
    else:
        outs = []
        for i in range(cfg.n_layers):
            take = lambda t: jax.tree.map(lambda a: a[i], t)  # noqa: E731
            x, sc = body(x, (take(params["dec_blocks"]),
                             take(cache["self"]), cache["cross_k"][i],
                             cache["cross_v"][i]))
            outs.append(sc)
        new_self = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
        new_cache = dict(cache, self=new_self)

    x = norm_apply(cfg.norm, params["dec_norm"], x)
    logits = linear(ctx, "lm_head", x, params["embed"].T)
    return logits, new_cache


def init_encdec_cache(ctx: Ctx, cfg: ArchConfig, params, batch: int,
                      seq_len: int, frames=None, dtype=jnp.bfloat16):
    """Self-attn cache + cross K/V (from encoder output if given)."""
    enc_cfg = cfg.replace(use_rope=False, sliding_window=0)
    sc, sa = L.init_attention_cache(enc_cfg, batch, seq_len, dtype)
    n = cfg.n_layers
    scs = jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape), sc)
    sas = jax.tree.map(lambda ax: ("layers",) + tuple(ax), sa,
                       is_leaf=lambda x: isinstance(x, tuple))
    kvshape = (n, batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
    cache = {"self": scs,
             "cross_k": jnp.zeros(kvshape, dtype),
             "cross_v": jnp.zeros(kvshape, dtype)}
    axes = {"self": sas,
            "cross_k": ("layers", "batch", "frames", "kv_heads", "head_dim"),
            "cross_v": ("layers", "batch", "frames", "kv_heads", "head_dim")}
    if frames is not None:
        enc_out = encdec_encode(ctx, cfg, params, frames)
        def kv_of(lp):
            _, k, v = L._qkv(ctx, enc_cfg, lp["cross"], enc_out)
            return k.astype(dtype), v.astype(dtype)
        ks, vs = jax.vmap(kv_of)(params["dec_blocks"])
        cache["cross_k"], cache["cross_v"] = ks, vs
    return cache, axes
