"""The paper's own evaluation workloads: Jet-DNN, VGG7, ResNet9.

Paper §V-A: "benchmark workloads from typical DNN applications, including
jet identification (Jet-DNN), image classification using VGG7 and ResNet9
networks.  The datasets used are: Jet-HLF, MNIST and SVHN."

Jet-DNN is the HLS4ML jet-tagging MLP (16 → 64 → 32 → 32 → 5, ReLU).
These models are the primary substrate for the PRUNING / SCALING /
QUANTIZATION strategy experiments (benchmarks/bench_pruning.py etc.).

All are functional JAX like the LM zoo: ``init(key, scale)`` → params, with
``scale`` the SCALING O-task's width multiplier.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import Ctx, dense_init, linear


# --------------------------------------------------------------- Jet-DNN
JET_FEATURES = 16
JET_CLASSES = 5
JET_WIDTHS = (64, 32, 32)


def init_jet_dnn(key, scale: float = 1.0, dtype=jnp.float32):
    widths = [max(2, int(round(w * scale))) for w in JET_WIDTHS]
    dims = [JET_FEATURES, *widths, JET_CLASSES]
    ks = jax.random.split(key, len(dims) - 1)
    params = {}
    for i, (din, dout) in enumerate(zip(dims, dims[1:])):
        params[f"fc{i}"] = {"w": dense_init(ks[i], din, dout, dtype),
                            "b": jnp.zeros((dout,), dtype)}
    return params


def jet_dnn_apply(ctx: Ctx, params, x):
    n = len(params)
    for i in range(n):
        p = params[f"fc{i}"]
        x = linear(ctx, f"fc{i}", x, p["w"], p["b"])
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


# ------------------------------------------------------------------ conv
def conv_init(key, k: int, cin: int, cout: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(k * k * cin)
    return (jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale
            ).astype(dtype)


def conv2d(x, w, stride: int = 1, padding: str = "SAME"):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)


def _bn_init(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _bn(p, x, eps=1e-5):
    # batch-independent norm (per-channel layernorm style) — keeps the
    # model purely functional without running statistics.
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=(1, 2), keepdims=True)
    var = jnp.var(xf, axis=(1, 2), keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ------------------------------------------------------------------ VGG7
VGG7_CHANNELS = (64, 64, 128, 128, 256, 256)


def init_vgg7(key, scale: float = 1.0, in_ch: int = 1, n_classes: int = 10,
              img: int = 28, dtype=jnp.float32):
    chans = [max(4, int(round(c * scale))) for c in VGG7_CHANNELS]
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    cin = in_ch
    for i, c in enumerate(chans):
        params[f"conv{i}"] = {"w": conv_init(ks[i], 3, cin, c, dtype),
                              "bn": _bn_init(c, dtype)}
        cin = c
    # three 2x pools over the six convs
    feat = (img // 8) ** 2 * chans[-1]
    params["fc"] = {"w": dense_init(ks[6], feat, n_classes, dtype),
                    "b": jnp.zeros((n_classes,), dtype)}
    return params


def vgg7_apply(ctx: Ctx, params, x):
    i = 0
    while f"conv{i}" in params:
        p = params[f"conv{i}"]
        x = conv2d(x, p["w"])
        x = _bn(p["bn"], x)
        x = jax.nn.relu(x)
        if i % 2 == 1:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
        i += 1
    x = x.reshape(x.shape[0], -1)
    return linear(ctx, "fc", x, params["fc"]["w"], params["fc"]["b"])


# --------------------------------------------------------------- ResNet9
RES9_CHANNELS = (64, 128, 256, 512)


def init_resnet9(key, scale: float = 1.0, in_ch: int = 3,
                 n_classes: int = 10, dtype=jnp.float32):
    chans = [max(4, int(round(c * scale))) for c in RES9_CHANNELS]
    ks = jax.random.split(key, 12)
    p: dict[str, Any] = {}
    p["stem"] = {"w": conv_init(ks[0], 3, in_ch, chans[0], dtype),
                 "bn": _bn_init(chans[0], dtype)}
    p["c1"] = {"w": conv_init(ks[1], 3, chans[0], chans[1], dtype),
               "bn": _bn_init(chans[1], dtype)}
    p["r1a"] = {"w": conv_init(ks[2], 3, chans[1], chans[1], dtype),
                "bn": _bn_init(chans[1], dtype)}
    p["r1b"] = {"w": conv_init(ks[3], 3, chans[1], chans[1], dtype),
                "bn": _bn_init(chans[1], dtype)}
    p["c2"] = {"w": conv_init(ks[4], 3, chans[1], chans[2], dtype),
               "bn": _bn_init(chans[2], dtype)}
    p["c3"] = {"w": conv_init(ks[5], 3, chans[2], chans[3], dtype),
               "bn": _bn_init(chans[3], dtype)}
    p["r2a"] = {"w": conv_init(ks[6], 3, chans[3], chans[3], dtype),
                "bn": _bn_init(chans[3], dtype)}
    p["r2b"] = {"w": conv_init(ks[7], 3, chans[3], chans[3], dtype),
                "bn": _bn_init(chans[3], dtype)}
    p["fc"] = {"w": dense_init(ks[8], chans[3], n_classes, dtype),
               "b": jnp.zeros((n_classes,), dtype)}
    return p


def _convbn(p, x, pool=False):
    x = conv2d(x, p["w"])
    x = _bn(p["bn"], x)
    if pool:
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return jax.nn.relu(x)


def resnet9_apply(ctx: Ctx, params, x):
    x = _convbn(params["stem"], x)
    x = _convbn(params["c1"], x, pool=True)
    r = _convbn(params["r1a"], x)
    r = _convbn(params["r1b"], r)
    x = x + r
    x = _convbn(params["c2"], x, pool=True)
    x = _convbn(params["c3"], x, pool=True)
    r = _convbn(params["r2a"], x)
    r = _convbn(params["r2b"], r)
    x = x + r
    x = jnp.max(x, axis=(1, 2))
    return linear(ctx, "fc", x, params["fc"]["w"], params["fc"]["b"])


# ------------------------------------------------------------- factories
BENCH_MODELS = {
    "jet_dnn": (init_jet_dnn, jet_dnn_apply,
                dict(features=JET_FEATURES, classes=JET_CLASSES,
                     input_shape=(JET_FEATURES,))),
    "vgg7": (init_vgg7, vgg7_apply,
             dict(classes=10, input_shape=(28, 28, 1))),
    "resnet9": (init_resnet9, resnet9_apply,
                dict(classes=10, input_shape=(32, 32, 3))),
}


def build_bench_model(name: str, key, scale: float = 1.0):
    init_fn, apply_fn, meta = BENCH_MODELS[name]
    params = init_fn(key, scale=scale)
    return params, apply_fn, meta
