"""Sub-quadratic sequence mixers: Mamba2 (SSD), mLSTM, sLSTM.

These power the `long_500k` shape: training/prefill uses chunked parallel
forms (O(S·L) with chunk L), decode carries an O(1) recurrent state.

Numerics notes:
- Mamba2 follows the minimal SSD formulation (chunked segsum) of the Mamba2
  paper, n_groups=1.
- mLSTM implements the stabilized exponential-gating chunkwise form of the
  xLSTM paper; tests validate the chunked form against the per-step
  recurrence (tests/test_ssm.py).
- sLSTM is inherently sequential (recurrent weights) — lax.scan over time.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Ctx, dense_init, linear, rmsnorm

NEG_INF = -1e30


# =====================================================================
# Mamba2
# =====================================================================
def mamba2_dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba2(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, nh, n, p_hd = mamba2_dims(cfg)
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 6)
    dt = cfg.pdt
    params: dict[str, Any] = {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * n + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[2], d_in, d, dt,
                               scale=1.0 / math.sqrt(d_in)),
    }
    axes = {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv_k", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",), "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm_scale": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, axes


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv over time.  xbc: (B,S,C); w: (K,C).

    With ``state`` (B,K-1,C): single-step decode — returns (y, new_state).
    """
    k = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, xbc], axis=1)       # (B,K,C)
        # accumulate in tap order, exactly like the full-sequence branch
        # below: the decode step then produces bit-identical conv outputs
        # to prefill, so chunked-vs-stepwise comparisons see only SSD-core
        # differences, not conv reduction-order dust
        y = sum(window[:, i].astype(jnp.float32)
                * w[i].astype(jnp.float32) for i in range(k))
        y = y + b.astype(jnp.float32)
        return jax.nn.silu(y)[:, None, :].astype(xbc.dtype), window[:, 1:]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted copies — K is tiny (4), this lowers to K fused muls.
    y = sum(pad[:, i:i + xbc.shape[1]].astype(jnp.float32)
            * w[i].astype(jnp.float32) for i in range(k))
    y = y + b.astype(jnp.float32)
    return jax.nn.silu(y).astype(xbc.dtype), None


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """segsum(a)[..., t, s] = sum_{j=s+1..t} a[..., j]; -inf for s>t.

    Computed by masked cumsum over the t axis, NOT as a difference of two
    inclusive cumsums: the decay exponents are same-signed and accumulate
    to O(chunk * |a|) magnitudes, so ``cs[t] - cs[s]`` loses
    ``eps * |cs|`` absolutely to cancellation — the worst case is a
    heavily padded final chunk, whose real steps all sit under the
    largest |cs| span (tests/test_ssm.py chunked-vs-stepwise[48]).  The
    masked-cumsum form builds each entry as a fresh short sum, keeping
    the chunked path ~an order of magnitude closer to the stepwise
    recurrence.
    """
    l = a.shape[-1]
    x = jnp.broadcast_to(a[..., :, None], a.shape + (l,))
    x = jnp.where(jnp.tril(jnp.ones((l, l), bool), k=-1), x, 0.0)
    seg = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, seg, NEG_INF)


def mamba2_apply(ctx: Ctx, cfg: ArchConfig, p, x,
                 state: dict | None = None):
    """Mamba2 mixer.  Returns (y, new_state).

    Train/prefill: chunked SSD.  Decode (ctx.decode, state given): recurrent
    single step with x (B,1,d).
    """
    b, s, d = x.shape
    d_in, nh, n, hd = mamba2_dims(cfg)
    proj = linear(ctx, "ssm/in_proj", x, p["in_proj"])
    z, xr, b_in, c_in, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    xbc = jnp.concatenate([xr, b_in, c_in], axis=-1)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)

    if state is not None and ctx.decode:
        xbc_c, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                         state["conv"])
        xc, bc, cc = jnp.split(xbc_c[:, 0], [d_in, d_in + n], axis=-1)
        xh = xc.reshape(b, nh, hd).astype(jnp.float32)
        dt1 = dt[:, 0]                                        # (B,H)
        da = jnp.exp(dt1 * a[None, :])                        # (B,H)
        # h: (B,H,hd,N)
        h_new = state["ssd"] * da[..., None, None] + \
            (dt1[..., None, None] * xh[..., None]
             * bc.astype(jnp.float32)[:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", h_new,
                       cc.astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(b, 1, d_in).astype(x.dtype)
        new_state = {"conv": conv_state, "ssd": h_new}
    else:
        xbc_c, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xc, bc, cc = jnp.split(xbc_c, [d_in, d_in + n], axis=-1)
        y, h_last = _ssd_chunked(cfg, xc, bc, cc, dt, a, p["D"])
        new_state = state
        if state is not None:  # prefill: leave final state for decode
            k = cfg.ssm_conv
            new_state = {"conv": xbc[:, -(k - 1):].astype(
                state["conv"].dtype), "ssd": h_last}
    yz = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yz = rmsnorm({"scale": p["norm_scale"]}, yz.astype(x.dtype))
    out = linear(ctx, "ssm/out_proj", yz, p["out_proj"])
    return out, new_state


def _ssd_chunked(cfg: ArchConfig, xc, bc, cc, dt, a, d_skip):
    """Chunked SSD.  xc: (B,S,d_in); bc/cc: (B,S,N); dt: (B,S,H)."""
    b, s, d_in = xc.shape
    _, nh, n, hd = mamba2_dims(cfg)
    l = min(cfg.ssm_chunk, s)
    s_orig = s
    if s % l:  # pad with dt=0 steps: decay=1, zero state contribution
        pad = l - s % l
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // l
    xh = xc.reshape(b, nc, l, nh, hd).astype(jnp.float32)
    bm = bc.reshape(b, nc, l, n).astype(jnp.float32)
    cm = cc.reshape(b, nc, l, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, l, nh)
    # per-step log decay (B,H,nc,L)
    da = (dtc * a[None, None, None, :]).transpose(0, 3, 1, 2)
    da_cs = jnp.cumsum(da, axis=-1)
    # intra-chunk (diagonal blocks)
    seg = _segsum(da)                                      # (B,H,nc,L,L)
    lmat = jnp.exp(seg)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcsh,bcshp->bclhp",
                        cm, bm, lmat, dtc, xh)
    # chunk-final states: decay from step l to chunk end is segsum's last
    # row (sum_{j>l} da_j) — reusing it avoids the cancellation-prone
    # ``da_cs[-1] - da_cs`` subtraction of two large cumsums
    decay_states = jnp.exp(seg[..., -1, :])                # (B,H,nc,L)
    states = jnp.einsum("bcln,bhcl,bclh,bclhp->bchpn",
                        bm, decay_states, dtc, xh)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[..., -1])                  # (B,H,nc)

    def scan_fn(h, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    states_t = states.transpose(1, 0, 2, 3, 4)             # (nc,B,H,P,N)
    dec_t = chunk_decay.transpose(2, 0, 1)                 # (nc,B,H)
    h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(scan_fn, h0, (states_t, dec_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)
    state_decay = jnp.exp(da_cs)                           # (B,H,nc,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cm, h_prevs, state_decay)
    y = y_diag + y_off
    y = y + d_skip.astype(jnp.float32)[None, None, None, :, None] * xh
    return y.reshape(b, s, d_in)[:, :s_orig], h_last


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_in, nh, n, hd = mamba2_dims(cfg)
    conv_dim = d_in + 2 * n
    return (
        {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
         "ssd": jnp.zeros((batch, nh, hd, n), jnp.float32)},
        {"conv": ("batch", None, "ssm_inner"),
         "ssd": ("batch", "ssm_heads", None, None)},
    )


# =====================================================================
# mLSTM (xLSTM matrix-memory cell)
# =====================================================================
def init_mlstm(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    d_in = 2 * d                       # up-projection factor 2
    dh = d_in // h
    ks = jax.random.split(key, 8)
    dt = cfg.pdt
    params = {
        "w_up": dense_init(ks[0], d, d_in, dt),
        "w_gate": dense_init(ks[1], d, d_in, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, d_in),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "wq": dense_init(ks[3], d_in, d_in, dt),
        "wk": dense_init(ks[4], d_in, d_in, dt),
        "wv": dense_init(ks[5], d_in, d_in, dt),
        "w_if": dense_init(ks[6], d_in, 2 * h, dt),
        "b_if": jnp.concatenate([jnp.zeros((h,), jnp.float32),
                                 3.0 * jnp.ones((h,), jnp.float32)]
                                ).astype(dt),
        "norm_scale": jnp.ones((d_in,), dt),
        "w_down": dense_init(ks[7], d_in, d, dt,
                             scale=1.0 / math.sqrt(d_in)),
    }
    axes = {
        "w_up": ("embed", "ssm_inner"), "w_gate": ("embed", "ssm_inner"),
        "conv_w": ("conv_k", "ssm_inner"), "conv_b": ("ssm_inner",),
        "wq": ("ssm_inner", "heads"), "wk": ("ssm_inner", "heads"),
        "wv": ("ssm_inner", "heads"), "w_if": ("ssm_inner", None),
        "b_if": (None,), "norm_scale": ("ssm_inner",),
        "w_down": ("ssm_inner", "embed"),
    }
    return params, axes


def mlstm_apply(ctx: Ctx, cfg: ArchConfig, p, x, state: dict | None = None):
    """mLSTM block.  Returns (y, new_state)."""
    b, s, d = x.shape
    h = cfg.n_heads
    d_in = 2 * d
    dh = d_in // h
    up = linear(ctx, "mlstm/w_up", x, p["w_up"])
    gate = linear(ctx, "mlstm/w_gate", x, p["w_gate"])

    if state is not None and ctx.decode:
        upc, conv_state = _causal_conv(up, p["conv_w"], p["conv_b"],
                                       state["conv"])
    else:
        upc, conv_state = _causal_conv(up, p["conv_w"], p["conv_b"])

    q = linear(ctx, "mlstm/wq", upc, p["wq"]).reshape(b, s, h, dh)
    k = linear(ctx, "mlstm/wk", upc, p["wk"]).reshape(b, s, h, dh) \
        / math.sqrt(dh)
    v = linear(ctx, "mlstm/wv", up, p["wv"]).reshape(b, s, h, dh)
    if_raw = linear(ctx, "mlstm/w_if", upc, p["w_if"],
                    p["b_if"]).astype(jnp.float32)
    logi, logf = if_raw[..., :h], jax.nn.log_sigmoid(if_raw[..., h:])

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if state is not None and ctx.decode:
        # recurrent step: state C (B,H,dk,dv), n (B,H,dk), m (B,H)
        li, lf = logi[:, 0], logf[:, 0]                    # (B,H)
        m_new = jnp.maximum(lf + state["m"], li)
        fp = jnp.exp(lf + state["m"] - m_new)
        ip = jnp.exp(li - m_new)
        kv = kf[:, 0, :, :, None] * vf[:, 0, :, None, :]   # (B,H,dk,dv)
        c_new = state["C"] * fp[..., None, None] + ip[..., None, None] * kv
        n_new = state["n"] * fp[..., None] + ip[..., None] * kf[:, 0]
        num = jnp.einsum("bhk,bhkv->bhv", qf[:, 0], c_new)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qf[:, 0], n_new))
        den = jnp.maximum(den, jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                # (B,1,H,dv)
        new_state = {"conv": conv_state, "C": c_new, "n": n_new,
                     "m": m_new}
    else:
        y, (c_f, n_f, m_f) = _mlstm_chunked(cfg, qf, kf, vf, logi, logf)
        new_state = state
        if state is not None:  # prefill → decode handoff
            kc = cfg.ssm_conv
            new_state = {"conv": up[:, -(kc - 1):].astype(
                state["conv"].dtype), "C": c_f, "n": n_f, "m": m_f}
    y = y.reshape(b, s, d_in)
    y = rmsnorm({"scale": p["norm_scale"]}, y.astype(x.dtype))
    y = y.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    return linear(ctx, "mlstm/w_down", y.astype(x.dtype), p["w_down"]), \
        new_state


def _mlstm_chunked(cfg: ArchConfig, q, k, v, logi, logf):
    """Stabilized chunkwise mLSTM.  q/k/v: (B,S,H,dh); logi/f: (B,S,H).

    Validated against mlstm_recurrent_reference in tests/test_ssm.py.
    """
    b, s, h, dh = q.shape
    l = min(cfg.mlstm_chunk, s)
    s_orig = s
    if s % l:  # pad: logf=0 (decay 1), logi=-inf (no input)
        pad = l - s % l
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=NEG_INF)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // l
    qc = q.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)  # (nc,B,H,L,dh)
    kc = k.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)
    lic = logi.reshape(b, nc, l, h).transpose(1, 0, 3, 2)     # (nc,B,H,L)
    lfc = logf.reshape(b, nc, l, h).transpose(1, 0, 3, 2)

    def chunk_fn(carry, inp):
        C, n, m = carry          # (B,H,dk,dv), (B,H,dk), (B,H)
        qj, kj, vj, li, lf = inp
        bcs = jnp.cumsum(lf, axis=-1)                         # (B,H,L)
        # D[t,s] = b_t - b_s + logi_s  (s<=t)
        dmat = bcs[..., :, None] - bcs[..., None, :] + li[..., None, :]
        mask = jnp.tril(jnp.ones((l, l), bool))
        dmat = jnp.where(mask, dmat, NEG_INF)
        m_intra = jnp.max(dmat, axis=-1)                      # (B,H,L)
        m_inter = m[..., None] + bcs
        m_row = jnp.maximum(m_intra, m_inter)                 # (B,H,L)
        sc = jnp.einsum("bhtd,bhsd->bhts", qj, kj) \
            * jnp.exp(dmat - m_row[..., None])
        inter_w = jnp.exp(m_inter - m_row)                    # (B,H,L)
        num = jnp.einsum("bhts,bhsv->bhtv", sc, vj) \
            + inter_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qj, C)
        den = jnp.einsum("bhts->bht", sc) \
            + inter_w * jnp.einsum("bhtd,bhd->bht", qj, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
        y = num / den[..., None]                              # (B,H,L,dv)
        # carry update
        g = bcs[..., -1]                                      # (B,H)
        w_t = li + g[..., None] - bcs                         # (B,H,L)
        m_new = jnp.maximum(m + g, jnp.max(w_t, axis=-1))
        scale_old = jnp.exp(m + g - m_new)
        wexp = jnp.exp(w_t - m_new[..., None])
        C_new = C * scale_old[..., None, None] + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", wexp, kj, vj)
        n_new = n * scale_old[..., None] + jnp.einsum(
            "bhs,bhsd->bhd", wexp, kj)
        return (C_new, n_new, m_new), y

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e9, jnp.float32)
    carry, ys = jax.lax.scan(chunk_fn, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)      # (B,S,H,dh)
    return y[:, :s_orig], carry


def init_mlstm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    h = cfg.n_heads
    d_in = 2 * cfg.d_model
    dh = d_in // h
    return (
        {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
         "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
         "n": jnp.zeros((batch, h, dh), jnp.float32),
         "m": jnp.full((batch, h), -1e9, jnp.float32)},
        {"conv": ("batch", None, "ssm_inner"),
         "C": ("batch", "heads", None, None),
         "n": ("batch", "heads", None),
         "m": ("batch", "heads")},
    )


def mlstm_recurrent_reference(cfg: ArchConfig, q, k, v, logi, logf):
    """Per-step recurrence — test oracle for the chunked form."""
    b, s, h, dh = q.shape

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        C_new = C * fp[..., None, None] + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n_new = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n_new)),
                          jnp.exp(-m_new))
        return (C_new, n_new, m_new), num / den[..., None]

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e9, jnp.float32)
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), logi.transpose(1, 0, 2),
          logf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, (c0, n0, m0), xs)
    return ys.transpose(1, 0, 2, 3)


# =====================================================================
# sLSTM (scalar-memory cell with recurrent block-diagonal weights)
# =====================================================================
def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    dt = cfg.pdt
    params = {
        "w_in": dense_init(ks[0], d, 4 * d, dt),
        "r": (jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32)
              / math.sqrt(dh)).astype(dt),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,), jnp.float32),
             3.0 * jnp.ones((d,), jnp.float32),      # f-gate bias
             jnp.zeros((d,), jnp.float32)]).astype(dt),
        "gn_scale": jnp.ones((d,), dt),
        "w_out": dense_init(ks[2], d, d, dt),
        # post-cell gated FFN (xLSTM sLSTM block, pf=4/3)
        "w_ff_up": dense_init(ks[3], d, (4 * d) // 3 * 2, dt),
        "w_ff_down": dense_init(jax.random.fold_in(key, 7),
                                (4 * d) // 3, d, dt),
    }
    axes = {
        "w_in": ("embed", None), "r": (None, "heads", None, None),
        "b": (None,), "gn_scale": ("embed",),
        "w_out": ("embed", "embed"),
        "w_ff_up": ("embed", "ffn"), "w_ff_down": ("ffn", "embed"),
    }
    return params, axes


def slstm_apply(ctx: Ctx, cfg: ArchConfig, p, x, state: dict | None = None):
    """sLSTM block: sequential scan over time.  Returns (y, new_state)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    wx = linear(ctx, "slstm/w_in", x, p["w_in"], p["b"]).astype(jnp.float32)
    wx = wx.reshape(b, s, 4, h, dh)
    r = p["r"].astype(jnp.float32)

    if state is not None and ctx.decode:
        carry = (state["h"], state["c"], state["n"], state["m"])
    else:
        zero = jnp.zeros((b, h, dh), jnp.float32)
        carry = (zero, zero, jnp.ones((b, h, dh), jnp.float32),
                 jnp.full((b, h, dh), 0.0, jnp.float32))

    def step(carry, wx_t):
        hp, cp, np_, mp = carry
        rec = jnp.einsum("ghij,bhi->gbhj", r, hp)          # (4,B,H,dh)
        zt = jnp.tanh(wx_t[:, 0] + rec[0])
        it = wx_t[:, 1] + rec[1]
        ft = wx_t[:, 2] + rec[2]
        ot = jax.nn.sigmoid(wx_t[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + mp, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(logf + mp - m_new)
        c_new = fp * cp + ip * zt
        n_new = fp * np_ + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    wx_t = wx.transpose(1, 0, 2, 3, 4)                     # (S,B,4,H,dh)
    carry, ys = jax.lax.scan(step, carry, wx_t)
    new_state = state
    if state is not None:
        new_state = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3]}
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    # per-head group norm
    yh = y.reshape(b, s, h, dh)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    y = ((yh - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(b, s, d)
    y = (y * p["gn_scale"].astype(jnp.float32)).astype(x.dtype)
    y = linear(ctx, "slstm/w_out", y, p["w_out"])
    # gated FFN
    ff = linear(ctx, "slstm/w_ff_up", y, p["w_ff_up"])
    f1, f2 = jnp.split(ff, 2, axis=-1)
    ffh = (jax.nn.gelu(f1.astype(jnp.float32))
           * f2.astype(jnp.float32)).astype(x.dtype)
    y = y + linear(ctx, "slstm/w_ff_down", ffh, p["w_ff_down"])
    return y, new_state


def init_slstm_state(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    zero = jnp.zeros((batch, h, dh), jnp.float32)
    return (
        {"h": zero, "c": zero, "n": jnp.ones_like(zero),
         "m": jnp.zeros_like(zero)},
        {k: ("batch", "heads", None) for k in ("h", "c", "n", "m")},
    )
