"""Per-layer mixed-precision policies (QUANTIZATION O-task substrate).

Paper §V-B: the QUANTIZATION O-task "operates at the HLS C++ level, providing
more direct control over hardware optimizations ... The resulting precision
configuration is directly instrumented into the C++ kernel."

TPU adaptation (DESIGN.md §2): there is no arbitrary-width datapath on a TPU;
the MXU natively supports bf16 / int8 / fp8.  A *policy* maps layer-name
patterns to precision levels on that lattice, and the model's ``linear``
primitive (models/layers.py) dispatches on the matched level — injecting the
policy into the computation right before lowering, the TPU-idiomatic
equivalent of rewriting the generated C++ source.

Levels (most → least precise): fp32 > bf16 > fp8 > int8.
"""

from __future__ import annotations

import fnmatch

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

FP32 = "fp32"
BF16 = "bf16"
FP8 = "fp8"      # float8_e4m3
INT8 = "int8"

# Lattice ordered most → least precise (greedy_lattice_descent walks down it).
LEVELS = (FP32, BF16, FP8, INT8)

# Bytes per weight at each level — the LUT/BRAM-analogue resource metric.
LEVEL_BYTES = {FP32: 4.0, BF16: 2.0, FP8: 1.0, INT8: 1.0}

_DTYPES = {
    FP32: jnp.float32,
    BF16: jnp.bfloat16,
    FP8: jnp.dtype(ml_dtypes.float8_e4m3fn),
    INT8: jnp.int8,
}


class PrecisionPolicy:
    """Ordered pattern→level map with an exemption list.

    Patterns are ``fnmatch`` globs matched against hierarchical layer names
    (e.g. ``layers/attn/wq``, ``layers/moe/experts/w_up``).  First match wins;
    unmatched names use ``default``.  ``exempt`` patterns always stay at the
    default level (router/gate weights etc., DESIGN.md §4).
    """

    def __init__(self, default: str = BF16,
                 rules: list[tuple[str, str]] | None = None,
                 exempt: list[str] | None = None):
        assert default in LEVELS
        self.default = default
        self.rules: list[tuple[str, str]] = list(rules or [])
        self.exempt: list[str] = list(exempt or [])

    def copy(self) -> "PrecisionPolicy":
        return PrecisionPolicy(self.default, list(self.rules),
                               list(self.exempt))

    def with_rule(self, pattern: str, level: str) -> "PrecisionPolicy":
        p = self.copy()
        # prepend so newer (more specific, search-driven) rules win
        p.rules.insert(0, (pattern, level))
        return p

    def level_for(self, name: str) -> str:
        for pat in self.exempt:
            if fnmatch.fnmatch(name, pat):
                return self.default
        for pat, level in self.rules:
            if fnmatch.fnmatch(name, pat):
                return level
        return self.default

    def as_dict(self) -> dict:
        return {"default": self.default, "rules": list(self.rules),
                "exempt": list(self.exempt)}

    def __repr__(self) -> str:
        return f"PrecisionPolicy({self.as_dict()})"


def quantize_int8(w: jnp.ndarray, axis: int = 0
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-output-channel int8 quantization of a weight matrix.

    ``axis`` is the *contraction* axis (reduced over), so scales are
    per-output-channel.  Returns (int8 weights, fp32 scales).
    """
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def fake_quant_int8(w: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Quantize→dequantize (straight-through estimator for training)."""
    q, scale = quantize_int8(w, axis)
    deq = q.astype(jnp.float32) * scale
    # STE: forward uses deq, gradient flows to w unchanged.
    return w + jax.lax.stop_gradient(deq - w.astype(jnp.float32)).astype(w.dtype)


def cast_level(w: jnp.ndarray, level: str) -> jnp.ndarray:
    """Round-trip a weight through the storage dtype of ``level``."""
    if level == INT8:
        q, scale = quantize_int8(w, axis=0)
        return (q.astype(jnp.float32) * scale).astype(w.dtype)
    dt = _DTYPES[level]
    return w.astype(dt).astype(w.dtype)


def weight_bytes(shape: tuple[int, ...], level: str) -> float:
    return float(np.prod(shape)) * LEVEL_BYTES[level]
