"""qwen2-7b — GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
28 heads % 16 != 0: heads replicate on the model axis; ffn/vocab shard
(sharding fallback recorded in EXPERIMENTS.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1000000.0,
    param_dtype="bfloat16", remat="dots",
)

SMOKE = CONFIG.replace(
    name="qwen2-smoke", n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
    head_dim=8, d_ff=96, vocab_size=256, param_dtype="float32",
    remat="none",
)
