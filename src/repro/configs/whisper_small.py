"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified].

12L (enc) + 12L (dec), d_model=768 12H d_ff=3072 vocab=51865.
Frontend stub: input_specs provides precomputed (B, 1500, 768) frame
embeddings (post-conv).  Decode shapes exercise the decoder with self-attn
KV cache + cross-attn cache over the 1500 encoder frames.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    enc_dec=True, n_enc_layers=12, n_frames=1500,
    norm="layernorm", mlp="gelu_mlp", use_rope=False,
    tie_embeddings=True,
    param_dtype="float32", remat="dots",
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, n_frames=16,
    remat="none",
)
