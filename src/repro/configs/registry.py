"""``--arch <id>`` resolution for all assigned architectures (+ the paper's
own benchmarks).  Each arch module exports CONFIG (full) and SMOKE
(reduced same-family config for CPU tests)."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPES, SMOKE_SHAPES, ShapeSpec

ARCH_IDS = [
    "xlstm_125m",
    "granite_moe_1b_a400m",
    "deepseek_v2_236b",
    "zamba2_2p7b",
    "h2o_danube_3_4b",
    "qwen1p5_110b",
    "qwen2_7b",
    "starcoder2_3b",
    "chameleon_34b",
    "whisper_small",
]

# canonical external ids → module names
ALIASES = {
    "xlstm-125m": "xlstm_125m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-2.7b": "zamba2_2p7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen1.5-110b": "qwen1p5_110b",
    "qwen2-7b": "qwen2_7b",
    "starcoder2-3b": "starcoder2_3b",
    "chameleon-34b": "chameleon_34b",
    "whisper-small": "whisper_small",
}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ArchConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


def get_shape(name: str, smoke: bool = False) -> ShapeSpec:
    return (SMOKE_SHAPES if smoke else SHAPES)[name]
