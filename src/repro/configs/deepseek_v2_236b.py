"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H d_ff=1536(expert) vocab=102400, MoE 160e top-6.
MLA: q_lora=1536, kv_lora=512, nope=128, rope=64, v_head=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab_size=102400,
    n_experts=160, top_k=6, d_expert=1536, n_shared_experts=2,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    rope_theta=10000.0,
    param_dtype="bfloat16", act_dtype="bfloat16", remat="full",
)

SMOKE = CONFIG.replace(
    name="deepseek-v2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=32, d_expert=32, n_experts=8, top_k=2,
    n_shared_experts=1, q_lora_rank=16, kv_lora_rank=16,
    rope_head_dim=8, nope_head_dim=16, v_head_dim=16, vocab_size=256,
    param_dtype="float32", remat="none",
)
