"""chameleon-34b — early-fusion, VQ image tokens [arXiv:2405.09818;
unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  QK-norm per the
chameleon paper.  Early fusion: image VQ tokens share the token stream
(frontend stub — input_specs provides the fused int32 token ids).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, rope_theta=10000.0,
    param_dtype="bfloat16", remat="full",
)

SMOKE = CONFIG.replace(
    name="chameleon-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, param_dtype="float32",
    remat="none",
)
