"""Architecture config schema + shape registry.

One ``ArchConfig`` describes any member of the supported model zoo
(dense / GQA / MLA / MoE / SSM / hybrid / enc-dec).  Each assigned
architecture gets a module ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full size) and ``SMOKE`` (reduced same-family config for CPU
tests).  ``repro.configs.registry`` resolves ``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 -> full attention
    use_rope: bool = True
    # norms / activations
    norm: str = "rmsnorm"
    mlp: str = "glu"                # glu | gelu_mlp
    mlp_bias: bool = False
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): shared attention block every `hybrid_period` layers
    hybrid_period: int = 0
    # xLSTM
    slstm_every: int = 0            # every k-th block is sLSTM (0 = none)
    mlstm_chunk: int = 256
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500
    max_decode_positions: int = 0   # 0 -> unlimited (learned pos off)
    # numerics / execution
    param_dtype: str = "float32"
    act_dtype: str = "bfloat16"
    attn_chunk: int = 1024          # memory-efficient attention kv-chunk
    remat: str = "dots"             # none | dots | full  (scan remat policy)
    scan_layers: bool = True
    # perf knobs (hillclimbed by SHARDING-SEARCH / §Perf; defaults = paper-
    # faithful baseline)
    pad_vocab_to_multiple: int = 0  # pad embed/lm_head so vocab shards
    mea_bf16: bool = False          # bf16 operands in MEA attention einsums
    loss_chunk: int = 0             # tokens per loss chunk (0 = one shot)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adt(self):
        return jnp.dtype(self.act_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear-attn / SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# smoke-scale shapes for CPU tests
SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 64, 4, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 128, 4, "decode"),
    "long_500k": ShapeSpec("long_500k", 256, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip policy of DESIGN.md §4."""
    if shape.name == "long_500k":
        if cfg.enc_dec:
            return False, "enc-dec audio backbone is length-bounded (1500 frames)"
        if not cfg.subquadratic:
            return False, "pure full-attention arch: 500k dense KV decode excluded"
    if shape.is_decode and cfg.enc_dec and cfg.n_layers == 0:
        return False, "encoder-only arch has no decode step"
    return True, ""


def model_flops_per_token(cfg: ArchConfig) -> float:
    """6*N(_active)*1 — MODEL_FLOPS per token for the roofline table."""
    n = active_params(cfg)
    return 6.0 * n


def active_params(cfg: ArchConfig) -> float:
    """Parameter count (active params for MoE) — analytic, no allocation."""
    d = cfg.d_model
    hd = cfg.hd
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    if cfg.family == "ssm" or cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        # mamba2 block: in_proj (z,x,B,C,dt) + conv + out_proj
        nh = d_in // cfg.ssm_head_dim
        per_layer += d * (2 * d_in + 2 * cfg.ssm_state + nh) + d_in * d
        per_layer += cfg.ssm_conv * (d_in + 2 * cfg.ssm_state)
    if cfg.family in ("dense", "moe", "vlm", "audio") or cfg.hybrid_period:
        # attention
        if cfg.use_mla:
            qdim = cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
            attn = d * cfg.q_lora_rank + cfg.q_lora_rank * qdim \
                if cfg.q_lora_rank else d * qdim
            attn += d * (cfg.kv_lora_rank + cfg.rope_head_dim)
            attn += cfg.kv_lora_rank * cfg.n_heads * (
                cfg.nope_head_dim + cfg.v_head_dim)
            attn += cfg.n_heads * cfg.v_head_dim * d
        else:
            attn = d * (cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd) \
                + cfg.n_heads * hd * d
        # mlp (active)
        if cfg.is_moe:
            mlp = cfg.top_k * 3 * d * cfg.d_expert \
                + cfg.n_shared_experts * 3 * d * cfg.d_expert
        else:
            mult = 3 if cfg.mlp == "glu" else 2
            mlp = mult * d * cfg.d_ff
        if cfg.hybrid_period:
            # shared block applied every hybrid_period layers; weights shared,
            # but *active* compute counts each application.
            frac = 1.0 / cfg.hybrid_period
            per_layer += frac * (attn + mlp)
        else:
            per_layer += attn + mlp
    if cfg.family == "ssm" and cfg.slstm_every:
        pass  # xLSTM per-layer terms handled in its config notes
    total = emb + cfg.n_layers * per_layer
    if cfg.enc_dec:
        # encoder layers + decoder cross-attention
        enc = cfg.n_enc_layers * (4 * d * d + 2 * d * cfg.d_ff)
        cross = cfg.n_layers * 4 * d * d
        total += enc + cross
    return float(total)


def total_params(cfg: ArchConfig) -> float:
    """Total parameter count (all experts for MoE)."""
    if not cfg.is_moe:
        return active_params(cfg)
    d = cfg.d_model
    act = active_params(cfg)
    routed_all = cfg.n_layers * cfg.n_experts * 3 * d * cfg.d_expert
    routed_active = cfg.n_layers * cfg.top_k * 3 * d * cfg.d_expert
    return act - routed_active + routed_all


def config_summary(cfg: ArchConfig) -> dict[str, Any]:
    return {
        "name": cfg.name, "family": cfg.family, "layers": cfg.n_layers,
        "d_model": cfg.d_model, "heads": cfg.n_heads,
        "kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
        "vocab": cfg.vocab_size,
        "params_total_B": total_params(cfg) / 1e9,
        "params_active_B": active_params(cfg) / 1e9,
    }
