"""h2o-danube-3-4b — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA window 4096.
SWA => KV cache bounded by the window: long_500k runs (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    sliding_window=4096, rope_theta=10000.0,
    param_dtype="bfloat16", remat="dots",
)

SMOKE = CONFIG.replace(
    name="danube-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, sliding_window=32,
    param_dtype="float32", remat="none",
)
