"""granite-moe-1b-a400m — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    n_experts=32, top_k=8, d_expert=512, n_shared_experts=0,
    tie_embeddings=True, rope_theta=10000.0,
    param_dtype="float32", remat="dots",
)

SMOKE = CONFIG.replace(
    name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=64, d_expert=64, n_experts=8, top_k=2,
    vocab_size=256, remat="none",
)
