"""zamba2-2.7b — Mamba2 backbone + weight-shared attention block
[arXiv:2411.15242; hf].

54L d_model=2560 32H d_ff=10240 vocab=32000, ssm_state=64.
Shared attention+MLP block applied every 6 mamba layers (9 applications).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=256, hybrid_period=6,
    tie_embeddings=True,
    param_dtype="bfloat16", remat="dots",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=32, hybrid_period=2, param_dtype="float32", remat="none",
)
