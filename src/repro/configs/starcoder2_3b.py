"""starcoder2-3b — GQA, RoPE [arXiv:2402.19173; hf].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
LayerNorm + bias MLP per the starcoder2 reference.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", mlp="gelu_mlp", mlp_bias=True, qkv_bias=True,
    rope_theta=100000.0, tie_embeddings=True,
    param_dtype="bfloat16", remat="dots",
)

SMOKE = CONFIG.replace(
    name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, param_dtype="float32",
    remat="none",
)
