"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own internal projections (mLSTM 2x up-proj; sLSTM pf=4/3 FFN).
Blocks alternate mLSTM / sLSTM (scan unit = one double block, 6 pairs).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    use_rope=False, tie_embeddings=True,
    slstm_every=2, mlstm_chunk=256,
    param_dtype="float32", remat="dots",
)

SMOKE = CONFIG.replace(
    name="xlstm-smoke", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    vocab_size=256, mlstm_chunk=32, remat="none",
)
