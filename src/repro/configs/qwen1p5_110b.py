"""qwen1.5-110b — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, rope_theta=1000000.0,
    param_dtype="bfloat16", remat="full",
)

SMOKE = CONFIG.replace(
    name="qwen110-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, param_dtype="float32", remat="none",
)
