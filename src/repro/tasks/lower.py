"""Lowering / compilation λ-tasks — the TPU-stack analogues of HLS4ML and
VIVADO-HLS (paper Table I).

LowerTask   (DNN → LOWERED):  jax.jit(step).lower(...) → StableHLO module.
CompileTask (LOWERED → COMPILED): .compile() → executable + analyses.
RooflineTask (COMPILED → COMPILED): annotates roofline terms (the "tool
report" of the RTL stage re-targeted to TPU; DESIGN.md §2).

These tasks work on LM handles; the shape/mesh come from the meta-model CFG
(keys ``target.shape`` / ``target.multi_pod`` ...), which is exactly how
the paper's λ-tasks read FPGA part number / clock period from the CFG.
"""

from __future__ import annotations

from repro.configs.base import SHAPES, shape_applicable
from repro.core.metamodel import (LEVEL_COMPILED, LEVEL_DNN, LEVEL_LOWERED,
                                  MetaModel)
from repro.core.task import LambdaTask, TaskError
from repro.launch.roofline import format_roofline, roofline


class Lower(LambdaTask):
    n_in = 1
    n_out = 1
    defaults = {
        "shape": "train_4k",
        "multi_pod": False,
        "fsdp": None,
        "microbatches": 1,
        "remat": None,
        "rules_overrides": None,
        "cache_seq_axis": None,
        "grad_compression": False,
    }

    def execute(self, meta: MetaModel, inputs):
        from repro.launch.dryrun import lower_cell  # sets XLA_FLAGS first
        art = meta.model(inputs[0])
        if art.level != LEVEL_DNN:
            raise TaskError(f"Lower expects a DNN artifact, got {art.level}")
        handle = art.payload
        if handle.kind != "lm":
            raise TaskError("Lower operates on LM handles (bench models "
                            "are evaluated at the DNN level)")
        shape = SHAPES[self.param(meta, "shape")]
        cfg = handle.model.cfg
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            raise TaskError(f"shape {shape.name} inapplicable: {why}")
        lowered, mesh, model, aux = lower_cell(
            handle.name, shape,
            multi_pod=self.param(meta, "multi_pod"),
            fsdp=self.param(meta, "fsdp"),
            microbatches=self.param(meta, "microbatches"),
            remat=self.param(meta, "remat"),
            rules_overrides=self.param(meta, "rules_overrides"),
            cache_seq_axis=self.param(meta, "cache_seq_axis"),
            grad_compression=self.param(meta, "grad_compression"))
        payload = {"lowered": lowered, "mesh": mesh, "model": model,
                   "shape": shape, "aux": aux}
        out = meta.add_model(f"{handle.name}@{shape.name}", LEVEL_LOWERED,
                             payload, parent=inputs[0],
                             metrics={"shape": shape.name, **aux})
        return [out]


class Compile(LambdaTask):
    n_in = 1
    n_out = 1
    defaults = {}

    def execute(self, meta: MetaModel, inputs):
        art = meta.model(inputs[0])
        if art.level != LEVEL_LOWERED:
            raise TaskError("Compile expects a LOWERED artifact")
        payload = dict(art.payload)
        compiled = payload["lowered"].compile()
        payload["compiled"] = compiled
        mem = compiled.memory_analysis()
        metrics = dict(art.metrics)
        try:
            metrics["temp_bytes"] = mem.temp_size_in_bytes
            metrics["arg_bytes"] = mem.argument_size_in_bytes
        except Exception:  # noqa: BLE001
            pass
        out = meta.add_model(art.name + ":rtl", LEVEL_COMPILED, payload,
                             parent=inputs[0], metrics=metrics)
        return [out]


class Roofline(LambdaTask):
    """Annotate a COMPILED artifact with roofline terms (report stage)."""
    n_in = 1
    n_out = 1
    defaults = {"model_flops": None, "verbose": True}

    def execute(self, meta: MetaModel, inputs):
        from repro.launch.dryrun import _cell_model_flops
        art = meta.model(inputs[0])
        if art.level != LEVEL_COMPILED:
            raise TaskError("Roofline expects a COMPILED artifact")
        p = art.payload
        mf = self.param(meta, "model_flops")
        if mf is None:
            mf = _cell_model_flops(p["model"].cfg.name, p["shape"])
        r = roofline(p["compiled"], p["mesh"], model_flops=mf)
        art.metrics.update(roofline=r)
        art.reports["roofline"] = format_roofline(art.name, r)
        if self.param(meta, "verbose"):
            print(art.reports["roofline"])
        meta.set("roofline.last", r)
        meta.record("roofline", artifact=art.name,
                    dominant=r["dominant"], bound_s=r["bound_s"])
        return [inputs[0]]
