"""MODEL-GEN λ-task (paper Table I: KERAS-MODEL-GEN, multiplicity 0-to-1).

Builds a model (bench CNN/MLP or LM arch), optionally trains it on the
configured dataset, and places the DNN-level artifact into the model space
with baseline accuracy + resource metrics.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.task import LambdaTask
from repro.data import synthetic
from repro.models.api import DEFAULT_EXEMPT, build_model
from repro.models.cnn import BENCH_MODELS
from repro.quant.policy import FP32, PrecisionPolicy
from repro.tasks.handle import DNNHandle
from repro.tasks.train_utils import train_classifier

BENCH_DATASETS = {"jet_dnn": "jet", "vgg7": "mnist_like",
                  "resnet9": "svhn_like"}


class ModelGen(LambdaTask):
    n_in = 0
    n_out = 1
    defaults = {
        "model": "jet_dnn",         # bench name or LM arch id
        "train_en": True,
        "train_epochs": 5,
        "train_samples": 3072,
        "batch": 128,
        "lr": 3e-3,
        "seed": 0,
        "smoke": False,             # LM archs: reduced config
        "scale": 1.0,
    }

    def execute(self, meta: MetaModel, inputs):
        name = self.param(meta, "model")
        seed = self.param(meta, "seed")
        key = jax.random.PRNGKey(seed)
        if name in BENCH_MODELS:
            handle = self._build_bench(meta, name, key)
        else:
            handle = self._build_lm(meta, name, key)
        acc = handle.evaluate()
        metrics = {"accuracy": acc, **handle.summary_metrics()}
        out = meta.add_model(name, LEVEL_DNN, handle, metrics=metrics)
        meta.record("model_gen", model=name, accuracy=acc)
        return [out]

    def _build_bench(self, meta, name, key) -> DNNHandle:
        init_fn, apply_fn, info = BENCH_MODELS[name]
        scale = self.param(meta, "scale")
        params = init_fn(key, scale=scale)
        ds_fn = synthetic.DATASETS[BENCH_DATASETS[name]]
        n = self.param(meta, "train_samples")
        x, y = ds_fn(n, seed=self.param(meta, "seed"))
        (xtr, ytr), (xte, yte) = synthetic.train_test_split(x, y)
        handle = DNNHandle(
            kind="bench", name=name, params=params, apply_fn=apply_fn,
            meta=dict(info), scale=scale,
            policy=PrecisionPolicy(default=FP32, exempt=DEFAULT_EXEMPT),
            train_data=(xtr, ytr), test_data=(xte, yte))
        if self.param(meta, "train_en"):
            params, losses = train_classifier(
                params, apply_fn, (xtr, ytr),
                epochs=self.param(meta, "train_epochs"),
                batch=self.param(meta, "batch"),
                lr=self.param(meta, "lr"),
                seed=self.param(meta, "seed"))
            handle = handle.child(params=params)
            meta.record("model_gen.train", model=name,
                        final_loss=losses[-1] if losses else None)
        return handle

    def _build_lm(self, meta, arch, key) -> DNNHandle:
        from repro.configs.registry import get_config
        cfg = get_config(arch, smoke=self.param(meta, "smoke"))
        model = build_model(cfg)
        params = model.init(key)
        # synthetic eval batch for next-token accuracy
        toks = synthetic.lm_tokens(8 * 128 + 1, cfg.vocab_size,
                                   seed=self.param(meta, "seed"))
        data = {"tokens": toks[:-1].reshape(8, 128),
                "labels": toks[1:].reshape(8, 128)}
        handle = DNNHandle(kind="lm", name=arch, params=params, model=model,
                           policy=model.policy, test_data=data,
                           train_data=data)
        if self.param(meta, "train_en"):
            from repro.tasks.train_utils import lm_finetune

            def batches(s):
                t = synthetic.lm_tokens(4 * 64 + 1, cfg.vocab_size, seed=s)
                return {"tokens": t[:-1].reshape(4, 64),
                        "labels": t[1:].reshape(4, 64)}

            params, _ = lm_finetune(model, params, batches,
                                    steps=self.param(meta, "train_epochs"))
            handle = handle.child(params=params)
        return handle
