"""SCALING O-task (paper §V-B, Table I).

"To accommodate a large DNN design on an FPGA, our framework supports the
SCALING O-task which automatically reduces the layer size while tracking
the accuracy loss (alpha_s).  The search stops when the loss exceeds
alpha_s."

With ``scale_auto`` the task walks a geometric ladder of width factors
(1/sqrt(2) steps by default), retraining at each width, and keeps the last
feasible one; with ``scale_auto=False`` it applies ``default_scale_factor``
once.  For LM handles scaling shrinks d_ff (and d_expert for MoE) — the
dominant-width analogue.
"""

from __future__ import annotations

import jax

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.search import monotone_shrink_search
from repro.core.task import OTask
from repro.models.cnn import BENCH_MODELS
from repro.tasks.handle import DNNHandle
from repro.tasks.train_utils import train_classifier


class Scaling(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "default_scale_factor": 0.5,
        "tolerate_acc_loss": 0.0005,   # alpha_s (paper: 0.05%)
        "scale_auto": True,
        "max_trials_num": 4,
        "train_epochs": 3,
        "lr": 3e-3,
        "seed": 0,
    }

    def execute(self, meta: MetaModel, inputs):
        art = meta.model(inputs[0])
        handle: DNNHandle = art.payload
        alpha = self.param(meta, "tolerate_acc_loss")
        base_acc = art.metrics.get("accuracy") or handle.evaluate()

        if self.param(meta, "scale_auto"):
            ladder = []
            s = handle.scale
            for _ in range(self.param(meta, "max_trials_num")):
                s = s / (2 ** 0.5)
                ladder.append(round(s, 4))
        else:
            ladder = [handle.scale
                      * self.param(meta, "default_scale_factor")]

        best: dict = {}

        def feasible(scale: float):
            probe = self._rebuild_at_scale(meta, handle, scale)
            acc = probe.evaluate()
            ok = (base_acc - acc) <= alpha
            meta.record("scaling.probe", scale=scale, accuracy=acc,
                        feasible=ok, **probe.resource_metrics())
            if ok:
                best.update(scale=scale, handle=probe, acc=acc)
            return ok, -scale, {"accuracy": acc}

        result = monotone_shrink_search(
            ladder, feasible, max_trials=self.param(meta, "max_trials_num"))
        if "handle" not in best:
            best.update(scale=handle.scale, handle=handle, acc=base_acc)
        out_handle = best["handle"]
        metrics = {"accuracy": best["acc"], "base_accuracy": base_acc,
                   "scale": best["scale"], "search_steps": result.n_steps,
                   **out_handle.summary_metrics()}
        out = meta.add_model(f"{handle.name}+S", LEVEL_DNN, out_handle,
                             parent=inputs[0], metrics=metrics)
        meta.record("scaling.done", scale=best["scale"],
                    accuracy=best["acc"])
        meta.set("scaling.result", metrics)
        return [out]

    def _rebuild_at_scale(self, meta, handle: DNNHandle,
                          scale: float) -> DNNHandle:
        seed = self.param(meta, "seed")
        key = jax.random.PRNGKey(seed + int(scale * 1e4))
        if handle.kind == "bench":
            init_fn, apply_fn, _ = BENCH_MODELS[handle.name.split("+")[0]]
            params = init_fn(key, scale=scale)
            params, _ = train_classifier(
                params, apply_fn, handle.train_data,
                epochs=self.param(meta, "train_epochs"),
                lr=self.param(meta, "lr"), policy=handle.policy, seed=seed)
            # masks no longer shape-compatible after scaling
            return handle.child(params=params, scale=scale, masks=None)
        # LM: shrink ffn widths, re-init, brief train
        cfg = handle.model.cfg
        rel = scale / handle.scale
        new_cfg = cfg.replace(
            d_ff=max(64, int(cfg.d_ff * rel) // 64 * 64) if cfg.d_ff else 0,
            d_expert=max(64, int(cfg.d_expert * rel) // 64 * 64)
            if cfg.d_expert else 0)
        from repro.models.api import build_model
        model = build_model(new_cfg, policy=handle.policy)
        params = model.init(key)
        from repro.tasks.train_utils import lm_finetune
        from repro.data.synthetic import lm_tokens

        def batches(s):
            t = lm_tokens(4 * 64 + 1, new_cfg.vocab_size, seed=200 + s)
            return {"tokens": t[:-1].reshape(4, 64),
                    "labels": t[1:].reshape(4, 64)}

        params, _ = lm_finetune(model, params, batches,
                                steps=self.param(meta, "train_epochs") * 4)
        return handle.child(params=params, model=model, scale=scale,
                            masks=None)
