"""DNN-level artifact payloads + resource metrics (DSP/LUT analogues).

A :class:`DNNHandle` is what lives in the meta-model's model space at
LEVEL_DNN — a model together with everything the O-tasks mutate:
pruning masks, the quantization policy, and the SCALING width factor.

Resource proxies (DESIGN.md §2):
- ``effective_macs``: multiply-accumulates per sample surviving pruning &
  scaling — the TPU analogue of DSP usage on a fully-unrolled FPGA design.
- ``weight_bits``: total weight storage bits under the quantization policy
  — the analogue of LUT/BRAM usage.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Ctx
from repro.quant.policy import LEVEL_BYTES, PrecisionPolicy
from repro.sparsity.masks import apply_masks, flatten_params


@dataclasses.dataclass
class DNNHandle:
    kind: str                       # "bench" | "lm"
    name: str
    params: Any
    apply_fn: Callable | None = None   # bench: (ctx, params, x) -> logits
    model: Any = None                  # lm: repro.models.api.LMModel
    meta: dict = dataclasses.field(default_factory=dict)
    scale: float = 1.0
    masks: dict | None = None
    policy: PrecisionPolicy | None = None
    train_data: tuple | None = None    # (x, y) or token batch dict
    test_data: tuple | None = None

    # ----------------------------------------------------------- compute
    def ctx(self) -> Ctx:
        return Ctx(policy=self.policy)

    def effective_params(self):
        p = self.params
        if self.masks:
            p = apply_masks(p, self.masks)
        return p

    def logits(self, x):
        return self.apply_fn(self.ctx(), self.effective_params(), x)

    # ---------------------------------------------------------- accuracy
    def evaluate(self, data=None, batch: int = 512) -> float:
        """Classification accuracy (bench) / next-token top-1 (lm)."""
        data = data if data is not None else self.test_data
        if self.kind == "bench":
            x, y = data
            correct = 0
            for i in range(0, len(x), batch):
                out = self.logits(jnp.asarray(x[i:i + batch]))
                correct += int(jnp.sum(jnp.argmax(out, -1)
                                       == jnp.asarray(y[i:i + batch])))
            return correct / len(x)
        # lm: data is {"tokens","labels"}
        m = self.model
        m2 = dataclasses.replace(m, policy=self.policy) \
            if self.policy is not None else m
        from repro.models import transformer as T
        logits, _ = T.lm_apply(m2.ctx(), m2.cfg, self.effective_params(),
                               jnp.asarray(data["tokens"]))
        pred = jnp.argmax(logits, -1)
        return float(jnp.mean(pred == jnp.asarray(data["labels"])))

    # ---------------------------------------------------- resource proxy
    def resource_metrics(self) -> dict[str, float]:
        flat = flatten_params(self.params)
        policy = self.policy or PrecisionPolicy()
        total_macs = 0.0
        alive_macs = 0.0
        weight_bits = 0.0
        spatial = self.meta.get("conv_spatial", {})
        for path, w in flat.items():
            if w.ndim < 2:
                continue
            n = float(np.prod(w.shape))
            # conv kernels act at every spatial position
            mult = float(spatial.get(path.split("/")[0], 1.0)) \
                if w.ndim == 4 else 1.0
            total_macs += n * mult
            if self.masks and path in self.masks:
                alive = float(jnp.sum(self.masks[path]))
            else:
                alive = n
            alive_macs += alive * mult
            level = policy.level_for(path)
            weight_bits += alive * LEVEL_BYTES[level] * 8
        return {
            "total_macs": total_macs,
            "effective_macs": alive_macs,
            "macs_fraction": alive_macs / max(1.0, total_macs),
            "weight_bits": weight_bits,
            "weight_mbytes": weight_bits / 8 / 1e6,
        }

    def summary_metrics(self) -> dict[str, float]:
        out = self.resource_metrics()
        out["scale"] = self.scale
        if self.masks:
            from repro.sparsity.masks import sparsity_report
            out.update(sparsity_report(self.masks))
        return out

    def child(self, **overrides) -> "DNNHandle":
        return dataclasses.replace(self, **overrides)
