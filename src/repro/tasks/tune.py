"""TUNE O-task: per-shape Pallas tile-config search (kernels/autotune.py).

The FPGA MetaML tunes low-level HLS knobs (unroll factors, partitioning);
the TPU analogue is Pallas kernel tiling.  TUNE closes the cross-stage loop:
it inspects the DNN-level artifact, derives the concrete kernel problems the
model will execute (matmul shapes from the weight matrices, the attention
shape from the arch config, block-sparse shapes from pruning masks), and
runs the autotuner's exhaustive tile search on each.  Every measured
candidate is republished as a ``SearchStep`` in the MetaModel history — a
tuning run reads exactly like a PRUNING or QUANTIZATION run in the logs —
and the winning configs are attached to the output artifact
(``handle.meta["tile_configs"]``) and to the shared CFG
(``tune.result``).

Multiplicity 1-to-1 (paper Table I): the model itself is unchanged; the
output artifact is a child whose metadata carries the tuned configs.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.task import OTask
from repro.tasks.handle import DNNHandle


class Tune(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "problems": "auto",      # "auto" | list of {"kernel":..., **problem}
        "batch_m": 128,          # synthetic M dim for matmul problems
        "max_problems": 4,       # cap on auto-derived problems (largest 1st)
        "max_trials": 16,        # per-problem candidate cap
        "iters": 3,              # timing iterations per candidate
        "warmup": 1,
        "cache_path": None,      # None -> autotune.default_cache_path()
        "force": False,          # re-measure even on a cache hit
        "interpret": None,       # None -> interpret unless on real TPU
    }

    def execute(self, meta: MetaModel, inputs):
        # deferred: pulls in the Pallas kernel modules, which flows
        # without a TUNE stage should not pay for at import time
        from repro.kernels import autotune

        art = meta.model(inputs[0])
        handle: DNNHandle = art.payload
        problems = self.param(meta, "problems")
        if problems == "auto":
            problems = derive_problems(
                handle, batch_m=self.param(meta, "batch_m"),
                max_problems=self.param(meta, "max_problems"))
        meta.record("tune.start", task=self.name,
                    problems=[p["kernel"] for p in problems])

        tuned: dict[str, dict[str, int]] = {}
        metrics: dict[str, Any] = {}
        total_steps = 0
        for i, spec in enumerate(problems):
            spec = dict(spec)
            kernel = spec.pop("kernel")
            result = autotune.tune(
                kernel, spec,
                cache_path=self.param(meta, "cache_path"),
                force=self.param(meta, "force"),
                interpret=self.param(meta, "interpret"),
                iters=self.param(meta, "iters"),
                warmup=self.param(meta, "warmup"),
                max_trials=self.param(meta, "max_trials"))
            # republish the search trace into the MetaModel history, one
            # probe event per measured tile config (cache hits are a single
            # zero-cost step, same shape as a pruning probe)
            if result.search is not None:
                for step in result.search.steps:
                    meta.record("tune.probe", kernel=kernel, key=result.key,
                                step=step.step, config=step.x,
                                us=step.info.get("us"),
                                vmem_bytes=step.info.get("vmem_bytes"),
                                feasible=step.feasible)
                total_steps += result.search.n_steps
            else:
                meta.record("tune.probe", kernel=kernel, key=result.key,
                            step=1, config=result.config, us=result.us,
                            cached=True, feasible=True)
                total_steps += 1
            tuned[result.key] = result.config
            # index-qualified: several problems may share a kernel
            metrics[f"tune.p{i}.{kernel}.us"] = result.us
            if result.default_us is not None:
                metrics[f"tune.p{i}.{kernel}.default_us"] = \
                    result.default_us
            meta.record("tune.done", kernel=kernel, key=result.key,
                        config=result.config, us=result.us,
                        cached=result.cached)

        out_handle = handle.child(
            meta={**handle.meta, "tile_configs": tuned})
        # carried parent metrics first: a chained second TUNE stage must
        # not have its fresh tune.* values shadowed by the stale carried
        # ones
        metrics = {**{k: v for k, v in art.metrics.items()
                      if isinstance(v, (int, float))},
                   **metrics,
                   "tune.problems": len(problems),
                   "tune.search_steps": total_steps}
        out = meta.add_model(f"{handle.name}+T", LEVEL_DNN, out_handle,
                             parent=inputs[0], metrics=metrics)
        meta.set("tune.result", {"configs": tuned,
                                 "search_steps": total_steps})
        return [out]


def derive_problems(handle: DNNHandle, *, batch_m: int = 128,
                    max_problems: int = 4) -> list[dict[str, Any]]:
    """Concrete kernel problems this model's forward pass executes.

    - quant_matmul: one problem per distinct 2D weight shape (K, N) with
      both dims tileable, activations (batch_m, K);
    - block_sparse_matmul: same shapes, for paths carrying a pruning mask
      at 128-block granularity (max_live read off the mask);
    - flash_attention: the arch config's (seq_len, heads, head_dim) when
      the handle wraps an LM;
    - flash_decode: the serving hot loop — one-token attention over the
      arch's decode cache (window-bounded under sliding-window attention),
      so TUNE picks the kv-split the deployed generate loop will run.
    - flash_decode_paged: the continuous-batching hot loop (linear caches
      only) — TUNE picks the page size the paged serving engine lays its
      pool out with.
    - flash_prefill_ragged: the batched admission-prefill dispatch (same
      gate) — TUNE picks the suffix q-tile against the tuned page size,
      which is also the prefix-sharing match granule.
    - paged_segment: the engine's decode-segment length (same gate) —
      the scheduler cadence that trades per-token dispatch overhead
      against boundary reactivity, keyed against the tuned page size.
      The resource manager's growth granule (pages per segment) follows
      from it, so both serving-schedule knobs are tuned quantities.
    Largest problems first, capped at ``max_problems``.
    """
    from repro.kernels import autotune
    from repro.sparsity.masks import flatten_params

    sized: list[tuple[int, dict[str, Any]]] = []
    seen: set[str] = set()
    flat = flatten_params(handle.params)
    for path, w in flat.items():
        if getattr(w, "ndim", 0) != 2:
            continue
        k, n = int(w.shape[0]), int(w.shape[1])
        if k < 32 or n < 32:
            continue
        prob = autotune.quant_matmul_problem((batch_m, k), (k, n), w.dtype)
        key = autotune.cache_key("quant_matmul", prob)
        if key not in seen:
            seen.add(key)
            sized.append((k * n, {"kernel": "quant_matmul", **prob}))
        mask = (handle.masks or {}).get(path)
        if mask is not None and k % 128 == 0 and n % 128 == 0:
            from repro.sparsity.masks import block_map
            occupancy = block_map(np.asarray(mask), 128)
            max_live = max(1, int(occupancy.sum(axis=0).max()))
            bprob = autotune.block_sparse_matmul_problem(
                (batch_m, k), (k, n), w.dtype, max_live=max_live)
            bkey = autotune.cache_key("block_sparse_matmul", bprob)
            if bkey not in seen:
                seen.add(bkey)
                sized.append((k * n,
                              {"kernel": "block_sparse_matmul", **bprob}))
    if handle.model is not None and getattr(handle.model.cfg,
                                            "n_heads", 0) > 0:
        cfg = handle.model.cfg
        hd = cfg.hd
        seq = min(int(getattr(cfg, "seq_len", 512) or 512), 512)
        prob = autotune.flash_attention_problem(
            (1, seq, cfg.n_heads, hd), (1, seq, cfg.n_kv_heads, hd),
            "float32", causal=True)
        sized.append((seq * seq * cfg.n_heads,
                      {"kernel": "flash_attention", **prob}))
        window = int(getattr(cfg, "sliding_window", 0) or 0)
        cache_len = min(seq, window) if window else seq
        # decode batch capped: the winning kv-split is batch-invariant
        # (the grid is parallel over batch*kv_heads) but interpret-mode
        # trial cost scales linearly with it.  dtype is the arch's
        # activation dtype — what layers.attention keys cached_config on
        # at serve time (q carries act_dtype there).
        db = min(batch_m, 8)
        adt = str(getattr(cfg, "act_dtype", "") or "float32")
        dprob = autotune.flash_decode_problem(
            (db, 1, cfg.n_heads, hd),
            (db, cache_len, cfg.n_kv_heads, hd), adt)
        # weighted like a full-cache prefill row so the serving hot loop
        # survives the max_problems cap alongside the big matmuls
        sized.append((seq * cache_len * cfg.n_heads,
                      {"kernel": "flash_decode", **dprob}))
        from repro.serving.paged_cache import supports_paging
        if supports_paging(cfg):
            # paged continuous-batching decode (dense-attention linear
            # caches only — the same gate the serving engine enforces, so
            # TUNE never spends trials on a kernel the arch cannot
            # dispatch): the tuned page_size reaches the engine through
            # serving/paged_cache.preferred_page_size at pool build time.
            pprob = autotune.flash_decode_paged_problem(
                db, cfg.n_heads, cfg.n_kv_heads, hd, cache_len, adt)
            sized.append((seq * cache_len * cfg.n_heads,
                          {"kernel": "flash_decode_paged", **pprob}))
            # batched ragged admission prefill: the other half of the
            # serving hot path.  Its page_size — which doubles as the
            # prefix-sharing match granule — is read back from the tuner's
            # flash_decode_paged winner (pure cache read; kernel default
            # on a cold cache), so TUNE tunes the suffix q-tile for the
            # pool layout it itself selects rather than for a constant.
            pps = int(autotune.tile_readback(
                "flash_decode_paged", pprob)[0]["page_size"])
            sbucket = min(int(seq), 32)
            fprob = autotune.flash_prefill_ragged_problem(
                db, sbucket, cfg.n_heads, cfg.n_kv_heads, hd, cache_len,
                pps, adt)
            sized.append((seq * cache_len * cfg.n_heads,
                          {"kernel": "flash_prefill_ragged", **fprob}))
            # decode-segment cadence: tuned against the same pool layout
            # (the page size TUNE selected above); the engine reads the
            # winner back via paged_cache.preferred_segment_len, and the
            # resource manager derives its growth granule from it
            gprob = autotune.paged_segment_problem(
                db, cfg.n_heads, cfg.n_kv_heads, hd, cache_len, pps, adt)
            sized.append((seq * cache_len * cfg.n_heads,
                          {"kernel": "paged_segment", **gprob}))
    sized.sort(key=lambda sp: -sp[0])
    return [p for _, p in sized[:max_problems]]
