"""SERVE O-task: staged search over the joint ServingPlan space.

TUNE picks per-kernel tile configs; SERVE closes the remaining gap
between a tuned model and a deployment: it searches the *joint* serving
configuration — pool geometry (page size, pages, oversubscription),
scheduler cadence (segment length, prefill bucket), growth/retention
policy — as one :class:`~repro.serving.plan.ServingPlan`, scored by
replaying a seeded :class:`~repro.serving.traffic.TrafficProfile`
through the real engine.

The search is two-staged (core/search.staged_search, uptune's
intermediate-feature idiom): stage 1 replays a shrunk profile and every
candidate's cheap intermediate features (admission latency, preemptions,
peak pages) land in the step trace; only the top-ranked survivors pay
for the full stage-2 replay.  The hand-assembled default plan is always
candidate 0 and always promoted to stage 2, so the searched winner is
gated against it on equal footing — the emitted plan is never worse
than the default on the profile's objective, by construction.

In a flow, SERVE sits after TUNE (``T → V``): TUNE persists its winning
tile configs to the autotune cache, and :meth:`ServingPlan.resolve`
reads page_size/segment_len back from that same cache when assembling
the default candidate — the cross-stage linkage is the on-disk cache,
same as the serving benches.  Every trial is republished as a
``SearchStep`` (``serve.probe`` events), and the winning plan is
attached to the output artifact (``handle.meta["serving_plan"]``), to
the shared CFG (``serve.result``), and — when ``artifact_path`` is set
— written as the deployable JSON artifact that
``ServingPlan.from_dict`` + ``PagedServingEngine.from_plan`` turn back
into the exact searched deployment.

Multiplicity 1-to-1 (paper Table I): the model is unchanged; the output
artifact is a child carrying the deployment plan.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.search import staged_search
from repro.core.task import OTask, TaskError
from repro.tasks.handle import DNNHandle


class Serve(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "profile": None,        # None -> tiny smoke profile; dict ok
        "slots": 4,             # concurrent decode slots
        "pool_slots": None,     # pool sized for fewer lifetimes (oversub)
        "tenants": (),          # TenantConfig roster for every candidate
        "n_replicas": 1,        # deployment shape (not a fitness term)
        "grid": None,           # None -> candidate_grid(default_plan)
        "keep": None,           # stage-2 survivors; None -> see execute()
        "stage1_frac": 0.5,     # stage-1 profile shrink factor
        "warm": 1,              # untimed warmup replays per trial
        "cache_path": None,     # autotune cache (None -> default path)
        "artifact_path": None,  # write winning plan JSON here
        "scorer": None,         # override: scorer(plan, stage) -> triple
    }

    def execute(self, meta: MetaModel, inputs):
        # deferred: pulls in the serving engine + Pallas kernels, which
        # flows without a SERVE stage should not pay for at import time
        from repro.serving.paged_cache import supports_paging
        from repro.serving.plan import ServingPlan
        from repro.serving.traffic import TrafficProfile, \
            make_replay_scorer

        art = meta.model(inputs[0])
        handle: DNNHandle = art.payload
        if handle.model is None or not supports_paging(handle.model.cfg):
            raise TaskError(
                f"{self.name}: input model does not support paged "
                "serving (needs an LM arch with dense-attention linear "
                "caches)")
        profile = self.param(meta, "profile")
        if profile is None:
            profile = TrafficProfile(name="smoke", n_requests=4,
                                     prompt_len=16, max_new_tokens=8)
        elif isinstance(profile, dict):
            profile = TrafficProfile.from_dict(profile)

        cfg = handle.model.cfg
        default_plan = ServingPlan.resolve(
            cfg, slots=self.param(meta, "slots"),
            max_prompt_len=profile.prompt_len,
            max_new_tokens=profile.max_new_tokens,
            pool_slots=self.param(meta, "pool_slots"),
            tenants=self.param(meta, "tenants"),
            n_replicas=self.param(meta, "n_replicas"),
            cache_path=self.param(meta, "cache_path"))
        grid = self.param(meta, "grid")
        if grid is None:
            grid = candidate_grid(default_plan)
        keep = self.param(meta, "keep")
        if keep is None:
            # worst case stage 2 runs keep+1 plans (survivors plus the
            # promoted default), so this keeps stage-2 replays at no more
            # than half the grid — the pruning the staged search is for
            keep = max(1, len(grid) // 2 - 1)
        scorer = self.param(meta, "scorer")
        if scorer is None:
            scorer = make_replay_scorer(
                handle.model, handle.params, profile,
                stage1_frac=self.param(meta, "stage1_frac"),
                warm=self.param(meta, "warm"))

        meta.record("serve.start", task=self.name, profile=profile.name,
                    n_candidates=len(grid), keep=keep)
        result = staged_search(
            grid, lambda p: scorer(p, 1), lambda p: scorer(p, 2),
            keep=keep, must_keep=(0,))
        for step in result.steps:
            meta.record("serve.probe", step=step.step,
                        stage=step.info.get("stage"),
                        page_size=step.x.cache.page_size,
                        segment_len=step.x.cache.segment_len,
                        n_pages=step.x.cache.n_pages,
                        objective=step.objective, feasible=step.feasible,
                        **{k: v for k, v in step.info.items()
                           if k not in ("stage",)})
        best = result.best_x
        if best is None:
            raise TaskError(f"{self.name}: no feasible plan on profile "
                            f"{profile.name!r}")
        stage2 = [s for s in result.steps if s.info.get("stage") == 2]
        default_obj = next(
            (s.objective for s in stage2 if s.info.get("candidate") == 0),
            None)
        n_stage2 = len(stage2)
        meta.record("serve.done", profile=profile.name,
                    objective=result.best_objective,
                    default_objective=default_obj,
                    n_stage2=n_stage2, n_pruned=len(grid) - n_stage2,
                    plan=best.to_dict())

        artifact_path = self.param(meta, "artifact_path")
        if artifact_path:
            with open(artifact_path, "w") as f:
                json.dump(best.to_dict(), f, indent=2, sort_keys=True)

        out_handle = handle.child(
            meta={**handle.meta, "serving_plan": best.to_dict()})
        metrics = {**{k: v for k, v in art.metrics.items()
                      if isinstance(v, (int, float))},
                   "serve.objective": result.best_objective,
                   "serve.n_candidates": len(grid),
                   "serve.n_stage2": n_stage2,
                   "serve.n_pruned": len(grid) - n_stage2}
        if default_obj is not None:
            metrics["serve.default_objective"] = default_obj
        out = meta.add_model(f"{handle.name}+V", LEVEL_DNN, out_handle,
                             parent=inputs[0], metrics=metrics)
        meta.set("serve.result", {
            "plan": best.to_dict(),
            "profile": profile.to_dict(),
            "objective": result.best_objective,
            "default_objective": default_obj,
            "n_candidates": len(grid),
            "n_stage2": n_stage2,
            "n_pruned": len(grid) - n_stage2,
        })
        return [out]


def _regeometry(plan, *, page_size: int | None = None,
                **cache_overrides: Any):
    """One grid neighbor: replace cache knobs, re-deriving the pool
    geometry when the page size changes (same ``blocks = ceil(cap /
    page_size)``, ``n_pages = pool * blocks + 1`` rule as
    :meth:`ServingPlan.resolve`), and mark the moved knobs as
    ``searched`` in provenance."""
    cache = plan.cache
    prov = dict(plan.provenance)
    if page_size is not None and page_size != cache.page_size:
        blocks = -(-plan.cap_tokens // page_size)
        pool = (cache.n_pages - 1) // cache.max_blocks
        cache = dataclasses.replace(cache, page_size=page_size,
                                    n_pages=pool * blocks + 1,
                                    max_blocks=blocks)
        prov["page_size"] = "searched"
    if cache_overrides:
        cache = dataclasses.replace(cache, **cache_overrides)
        for k in cache_overrides:
            prov[k] = "searched"
    return dataclasses.replace(plan, cache=cache, provenance=prov)


def candidate_grid(default_plan) -> list:
    """The SERVE search space: the resolved default plan first (index 0
    — the staged search pins it to stage 2 as the gate baseline), then
    its one-knob neighbors: page size halved/doubled (pool geometry
    re-derived), segment cadence halved/doubled, a smaller prefill
    admission bucket, growth-on-demand enabled, and retention-assisted
    restore enabled.  Deduplicated on the effective cache config."""
    c = default_plan.cache
    cands = [default_plan]
    if c.page_size // 2 >= 4:
        cands.append(_regeometry(default_plan,
                                 page_size=c.page_size // 2))
    cands.append(_regeometry(default_plan, page_size=c.page_size * 2))
    if c.segment_len // 2 >= 2:
        cands.append(_regeometry(default_plan,
                                 segment_len=c.segment_len // 2))
    cands.append(_regeometry(default_plan,
                             segment_len=c.segment_len * 2))
    if c.prefill_bucket // 2 >= 1:
        cands.append(_regeometry(default_plan,
                                 prefill_bucket=c.prefill_bucket // 2))
    cands.append(_regeometry(default_plan, growth_pages=c.max_blocks))
    cands.append(_regeometry(default_plan, retain_pages=c.max_blocks))
    seen: set[str] = set()
    out = []
    for p in cands:
        key = json.dumps(p.cache.to_dict(), sort_keys=True)
        if key not in seen:
            seen.add(key)
            out.append(p)
    return out
