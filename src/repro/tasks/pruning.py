"""PRUNING O-task (paper §V-B, Table I).

    maximize   pruning_rate
    subject to accuracy_loss(pruning_rate) <= tolerate_acc_loss (alpha_p)

Auto-pruning binary search over the rate, terminating when the bracket is
below ``pruning_rate_thresh`` (beta_p) — `1 + log2(1/beta_p)` probes.  Each
probe builds magnitude masks at the candidate rate, fine-tunes briefly with
the masks projected after every update (gradually ramped), and evaluates
accuracy.  The feasible candidate with the highest rate is selected (paper
Fig. 3/4); its masks and fine-tuned weights form the output artifact.

TPU note (DESIGN.md §2): default granularity is 128x128 blocks so zero
blocks are *structurally* skippable by the block-sparse Pallas kernel;
``granularity="unstructured"`` reproduces the paper's curves exactly.
"""

from __future__ import annotations

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.search import binary_search_max
from repro.core.task import OTask
from repro.sparsity.masks import (build_masks, polynomial_schedule,
                                  prunable_paths)
from repro.tasks.handle import DNNHandle
from repro.tasks.train_utils import lm_finetune, train_classifier


class Pruning(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "tolerate_acc_loss": 0.02,     # alpha_p
        "pruning_rate_thresh": 0.02,   # beta_p
        "train_epochs": 2,
        "granularity": "auto",         # auto | block | unstructured
        "block": 128,
        "max_rate": 1.0,
        "lr": 1e-3,
    }

    def execute(self, meta: MetaModel, inputs):
        art = meta.model(inputs[0])
        handle: DNNHandle = art.payload
        alpha = self.param(meta, "tolerate_acc_loss")
        beta = self.param(meta, "pruning_rate_thresh")
        gran = self.param(meta, "granularity")
        block = self.param(meta, "block")
        epochs = self.param(meta, "train_epochs")

        base_acc = art.metrics.get("accuracy")
        if base_acc is None:
            base_acc = handle.evaluate()
        paths = prunable_paths(handle.params, min_size=64)
        if gran == "auto":
            # block granularity is only meaningful when weights span
            # multiple MXU tiles; small bench nets prune unstructured
            # (paper-faithful), large LM mats prune at tile granularity.
            from repro.sparsity.masks import flatten_params
            flat = flatten_params(handle.params)
            biggest = max((max(flat[p].shape) for p in paths), default=0)
            gran = "block" if biggest >= 4 * block else "unstructured"
            meta.record("pruning.granularity", chosen=gran)
        best: dict = {}

        def feasible(rate: float):
            if rate <= 0.0:
                acc = base_acc
                meta.record("pruning.probe", rate=0.0, accuracy=acc)
                return True, 0.0, {"accuracy": acc}
            trained, masks = self._finetune_at_rate(
                handle, rate, paths, gran, block, epochs)
            probe = handle.child(params=trained, masks=masks)
            acc = probe.evaluate()
            ok = (base_acc - acc) <= alpha
            meta.record("pruning.probe", rate=rate, accuracy=acc,
                        feasible=ok, **probe.resource_metrics())
            if ok and rate >= best.get("rate", -1.0):
                best.update(rate=rate, handle=probe, acc=acc)
            return ok, rate, {"accuracy": acc}

        result = binary_search_max(feasible, lo=0.0,
                                   hi=self.param(meta, "max_rate"),
                                   beta=beta)
        if "handle" not in best:   # nothing feasible beyond 0%
            best.update(rate=0.0, handle=handle, acc=base_acc)
        out_handle = best["handle"]
        metrics = {"accuracy": best["acc"], "base_accuracy": base_acc,
                   "pruning_rate": best["rate"],
                   "search_steps": result.n_steps,
                   **out_handle.summary_metrics()}
        out = meta.add_model(f"{handle.name}+P", LEVEL_DNN, out_handle,
                             parent=inputs[0], metrics=metrics)
        meta.record("pruning.done", rate=best["rate"], accuracy=best["acc"],
                    steps=result.n_steps)
        meta.set("pruning.result", metrics)
        return [out]

    def _finetune_at_rate(self, handle: DNNHandle, rate, paths, gran,
                          block, epochs):
        lr = self.params.get("lr", type(self).defaults["lr"])
        if handle.kind == "bench":
            n = len(handle.train_data[0])
            steps_total = max(1, epochs * max(1, n // 128))
            ramp_end = max(1, steps_total // 2)

            def mask_schedule(step):
                r = polynomial_schedule(step, 0, ramp_end, rate)
                return build_masks(handle.params, r, gran, paths, block)

            final_masks = build_masks(handle.params, rate, gran, paths,
                                      block)
            trained, _ = train_classifier(
                handle.params, handle.apply_fn, handle.train_data,
                epochs=epochs, lr=lr, policy=handle.policy,
                mask_schedule=lambda s: (mask_schedule(s)
                                         if s < ramp_end else final_masks))
            return trained, final_masks
        # LM: direct masks + brief fine-tune
        masks = build_masks(handle.params, rate, gran, paths, block)
        cfg = handle.model.cfg

        def batches(s):
            from repro.data.synthetic import lm_tokens
            t = lm_tokens(4 * 64 + 1, cfg.vocab_size, seed=100 + s)
            return {"tokens": t[:-1].reshape(4, 64),
                    "labels": t[1:].reshape(4, 64)}

        trained, _ = lm_finetune(handle.model, handle.params, batches,
                                 steps=epochs * 4, masks=masks)
        return trained, masks
