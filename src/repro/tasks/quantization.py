"""QUANTIZATION O-task (paper §V-B, Table I).

Paper: operates at the HLS C++ level via source-to-source transformation;
per-layer mixed precision accepted while accuracy loss < alpha_q, repeated
until no further move helps.

TPU adaptation (DESIGN.md §2): the precision lattice is the MXU-native
{fp32 > bf16 > fp8 > int8}; the per-layer policy is injected into every
``linear`` call at lowering time (models/common.py), the TPU-idiomatic
equivalent of instrumenting the generated C++ kernel.  The greedy descent
walks each layer down the lattice, keeping moves whose accuracy loss stays
within alpha_q — same objective, same acceptance rule, different lattice.
"""

from __future__ import annotations

from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.search import greedy_lattice_descent
from repro.core.task import OTask
from repro.quant.policy import BF16, FP8, INT8, LEVELS, PrecisionPolicy
from repro.sparsity.masks import flatten_params
from repro.tasks.handle import DNNHandle


def quantizable_groups(handle: DNNHandle) -> list[str]:
    """Layer-name patterns the policy can move down the lattice."""
    if handle.kind == "bench":
        flat = flatten_params(handle.params)
        groups = sorted({p.split("/")[0] for p in flat})
        return [g for g in groups if not g.startswith(("bn", "norm"))]
    # lm: one group per linear site inside the block (policy patterns)
    cfg = handle.model.cfg
    groups = ["lm_head"]
    if cfg.use_mla:
        groups += ["attn/wq_b", "attn/wkv_a", "attn/wkv_b", "attn/wo"]
    elif cfg.family not in ("ssm",):
        groups += ["attn/wq", "attn/wk", "attn/wv", "attn/wo"]
    if cfg.is_moe:
        groups += ["moe/experts", "mlp/*"]
    elif cfg.d_ff:
        groups += ["mlp/*"]
    if cfg.family == "ssm":
        groups += ["mlstm/*", "slstm/w_in", "slstm/w_out", "slstm/*ff*"]
    if cfg.family == "hybrid":
        groups += ["ssm/in_proj", "ssm/out_proj", "attn/*", "mlp/*"]
    return groups


class Quantization(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "tolerate_acc_loss": 0.01,    # alpha_q
        "start_level": BF16,
        "levels": (BF16, FP8, INT8),
        "passes": 2,
    }

    def execute(self, meta: MetaModel, inputs):
        art = meta.model(inputs[0])
        handle: DNNHandle = art.payload
        alpha = self.param(meta, "tolerate_acc_loss")
        levels = list(self.param(meta, "levels"))
        start = self.param(meta, "start_level")
        assert all(lv in LEVELS for lv in levels)
        base_acc = art.metrics.get("accuracy") or handle.evaluate()
        base_policy = handle.policy or PrecisionPolicy()
        groups = quantizable_groups(handle)

        state: dict = {"best": None}

        def accept(assignment: dict[str, str]):
            policy = PrecisionPolicy(default=base_policy.default,
                                     exempt=base_policy.exempt)
            for pat, lv in assignment.items():
                policy = policy.with_rule(f"*{pat}*", lv)
            probe = handle.child(policy=policy)
            acc = probe.evaluate()
            ok = (base_acc - acc) < alpha
            meta.record("quantization.probe",
                        assignment={k: str(v) for k, v in
                                    assignment.items()},
                        accuracy=acc, feasible=ok,
                        weight_bits=probe.resource_metrics()["weight_bits"])
            if ok:
                state["best"] = (probe, acc, assignment)
            return ok, acc, {"accuracy": acc}

        assignment, result = greedy_lattice_descent(
            groups, levels, accept, start_level=start,
            passes=self.param(meta, "passes"))

        if state["best"] is None:
            probe, acc = handle, base_acc
            assignment = {g: start for g in groups}
        else:
            probe, acc, assignment = state["best"]
        metrics = {"accuracy": acc, "base_accuracy": base_acc,
                   "assignment": {k: str(v) for k, v in assignment.items()},
                   "search_steps": result.n_steps,
                   **probe.summary_metrics()}
        out = meta.add_model(f"{handle.name}+Q", LEVEL_DNN, probe,
                             parent=inputs[0], metrics=metrics)
        meta.record("quantization.done", accuracy=acc,
                    weight_bits=metrics["weight_bits"])
        meta.set("quantization.result", metrics)
        return [out]
