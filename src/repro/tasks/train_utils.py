"""Small training loops used inside O-tasks (fine-tune under masks,
retrain after scaling) — pure JAX, jit-compiled per (model, mask) combo."""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Ctx
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm
from repro.sparsity.masks import apply_masks


def softmax_xent(logits, labels):
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_classifier(params, apply_fn: Callable, train_data, *,
                     epochs: int = 3, batch: int = 128, lr: float = 3e-3,
                     masks: dict | None = None,
                     policy=None, seed: int = 0,
                     mask_schedule: Callable[[int], dict] | None = None):
    """Train/fine-tune a classifier.  ``masks`` are re-applied after every
    update (projected masked training — the Keras pruning recipe the paper
    uses).  ``mask_schedule(step)`` overrides masks per step for gradual
    sparsity ramps."""
    x, y = train_data
    n = len(x)
    steps_per_epoch = max(1, n // batch)
    opt = adamw(lr, weight_decay=1e-4)
    opt_state = opt.init(params)
    ctx = Ctx(policy=policy)

    @jax.jit
    def step_fn(params, opt_state, xb, yb, cur_masks):
        def loss_fn(p):
            if cur_masks is not None:
                p = apply_masks(p, cur_masks)
            return softmax_xent(apply_fn(ctx, p, xb), yb)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        if cur_masks is not None:
            params = apply_masks(params, cur_masks)
        return params, opt_state, loss

    rng = np.random.default_rng(seed)
    losses = []
    global_step = 0
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(steps_per_epoch):
            idx = perm[i * batch:(i + 1) * batch]
            cur = mask_schedule(global_step) if mask_schedule else masks
            params, opt_state, loss = step_fn(
                params, opt_state, jnp.asarray(x[idx]), jnp.asarray(y[idx]),
                cur)
            losses.append(float(loss))
            global_step += 1
    return params, losses


def lm_finetune(model, params, token_batches, *, steps: int = 20,
                lr: float = 1e-3, masks: dict | None = None):
    """Brief LM fine-tune under masks (used by O-tasks on LM archs)."""
    opt = adamw(lr)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            if masks is not None:
                p = apply_masks(p, masks)
            loss, _ = model.loss(p, batch)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        if masks is not None:
            params = apply_masks(params, masks)
        return params, opt_state, loss

    losses = []
    for s in range(steps):
        batch = token_batches(s)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
    return params, losses
