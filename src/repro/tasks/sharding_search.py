"""SHARDING-SEARCH O-task — the TPU-platform-specific optimization knob.

No FPGA analogue exists (DESIGN.md §2): on TPU the expert-tuned knob is the
parallelism layout.  This O-task automates it exactly the way PRUNING
automates the sparsity knob: enumerate candidate configurations (remat
policy, microbatching, cache-sequence sharding axis, FSDP on/off), lower +
compile each, and keep the one minimizing the roofline bound.  Greedy
coordinate descent — each knob is tried against the incumbent.

objective:  minimize   max(compute_s, memory_s, collective_s)
constraint: fits HBM (peak bytes/chip <= 16 GB)
"""

from __future__ import annotations

from repro.configs.base import SHAPES
from repro.core.metamodel import LEVEL_DNN, MetaModel
from repro.core.task import OTask, TaskError
from repro.launch.roofline import HW, roofline


class ShardingSearch(OTask):
    n_in = 1
    n_out = 1
    defaults = {
        "shape": "train_4k",
        "multi_pod": False,
        "knobs": None,          # {name: [candidates]} override
        "require_fit": True,
        "verbose": True,
    }

    BASE = {"remat": None, "microbatches": 1, "cache_seq_axis": None,
            "fsdp": None}
    TRAIN_KNOBS = {
        "remat": ["dots", "full", "none"],
        "microbatches": [1, 2, 4],
        "fsdp": [None, True],
    }
    DECODE_KNOBS = {
        "cache_seq_axis": [None, "model", "data"],
    }

    def execute(self, meta: MetaModel, inputs):
        from repro.launch.dryrun import _cell_model_flops, lower_cell
        art = meta.model(inputs[0])
        if art.level != LEVEL_DNN or art.payload.kind != "lm":
            raise TaskError("ShardingSearch expects an LM DNN artifact")
        handle = art.payload
        shape = SHAPES[self.param(meta, "shape")]
        multi_pod = self.param(meta, "multi_pod")
        knobs = self.param(meta, "knobs")
        if knobs is None:
            knobs = dict(self.TRAIN_KNOBS if shape.kind == "train"
                         else self.DECODE_KNOBS)
        verbose = self.param(meta, "verbose")
        require_fit = self.param(meta, "require_fit")
        mf = _cell_model_flops(handle.name, shape)

        def measure(cfg_kw: dict) -> dict:
            lowered, mesh, model, aux = lower_cell(
                handle.name, shape, multi_pod=multi_pod, **cfg_kw)
            compiled = lowered.compile()
            r = roofline(compiled, mesh, model_flops=mf)
            meta.record("sharding_search.probe", config=dict(cfg_kw),
                        bound_s=r["bound_s"], dominant=r["dominant"],
                        fits=r.get("fits_hbm"))
            if verbose:
                print(f"  probe {cfg_kw}: bound={r['bound_s']*1e3:.2f}ms "
                      f"dom={r['dominant']} fits={r.get('fits_hbm')}")
            return r

        def score(r: dict) -> float:
            s = r["bound_s"]
            if require_fit and r.get("fits_hbm") is False:
                peak = r["memory"].get("peak_bytes", 0)
                s += 10.0 * max(0.0, peak / HW["hbm_bytes"] - 1.0)
            return s

        incumbent = dict(self.BASE)
        best_r = measure(incumbent)
        trace = [{"config": dict(incumbent), "roofline": best_r}]
        for knob, candidates in knobs.items():
            for cand in candidates:
                if cand == incumbent.get(knob):
                    continue
                trial = dict(incumbent, **{knob: cand})
                try:
                    r = measure(trial)
                except Exception as e:  # noqa: BLE001
                    meta.record("sharding_search.error",
                                config=trial, error=repr(e))
                    continue
                trace.append({"config": dict(trial), "roofline": r})
                if score(r) < score(best_r):
                    incumbent, best_r = trial, r
        metrics = {"best_config": incumbent,
                   "bound_s": best_r["bound_s"],
                   "dominant": best_r["dominant"],
                   "n_probes": len(trace)}
        out_handle = handle.child(
            meta=dict(handle.meta, sharding_config=incumbent))
        out = meta.add_model(f"{handle.name}+Sh", LEVEL_DNN, out_handle,
                             parent=inputs[0],
                             metrics={**art.metrics, **metrics})
        meta.set("sharding_search.result",
                 {"best": incumbent, "roofline": best_r, "trace": trace})
        return [out]
