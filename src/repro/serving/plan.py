"""ServingPlan: the single declarative serving artifact.

PRs 3–7 built every serving mechanism, but deployment stayed
hand-assembled: pool geometry lived in :class:`PagedCacheConfig`
construction sites, kernel tile choices in ad-hoc
``preferred_page_size``/``preferred_segment_len`` readbacks, tenant
quotas in engine kwargs, and cluster shape in ``ServingCluster`` kwargs.
A :class:`ServingPlan` folds all of it into one frozen, JSON-round-trip
dataclass:

- the paged-cache geometry and scheduler cadence (``cache``);
- how each tuned knob was obtained (``provenance``: the
  :meth:`resolve` step reads page_size and segment_len back from the
  autotuner's persisted cache through the consolidated
  :func:`repro.kernels.autotune.tile_readback` and records per knob
  whether the value was ``tuned``/``relaxed``/``default``/``explicit``);
- admission/growth/retention policy (all `PagedCacheConfig` fields:
  ``prefill_bucket``, ``growth_pages``, ``retain_pages``,
  prefix-sharing flags);
- the tenant roster and the cluster shape (``n_replicas``,
  :class:`HealthPolicy`);
- the durability story (:class:`DurabilityPolicy`: whether runs built
  from the plan keep a write-ahead request journal, where it lives,
  its fsync cadence and segment rotation size — serving/journal.py);
- the workload sizing the pool was resolved against
  (``max_prompt_len``/``max_new_tokens``), so a loaded plan can
  re-validate or re-resolve.

Deployment is then one call: ``PagedServingEngine.from_plan(model,
plan)`` or ``ServingCluster.from_plan(model, params, plan)``.  The
kwargs constructors stay as thin compat layers that assemble a plan
internally, so every pre-existing call site keeps working while the
plan remains the single source of truth (``engine.plan``).

``to_dict``/``from_dict`` follow PagedCacheConfig's checkpoint-compat
contract — unknown keys dropped, missing keys defaulted — applied
recursively through the nested config dataclasses, so a plan JSON
written before a knob existed (or after one is retired) stays loadable
bit-for-bit on the fields both sides know.

The SERVE design-flow task (tasks/serve.py) searches the space of these
plans and emits the winner as a deployable JSON artifact.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.serving.paged_cache import PagedCacheConfig
from repro.serving.resources import TenantConfig


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Boundary-heartbeat thresholds.  A replica beats once per round it
    steps; ``suspect_after`` consecutive misses mark it SUSPECT (still
    routed as a last resort, still stepped), ``dead_after`` mark it DEAD
    (fenced + salvaged).  One dropped heartbeat with stepping intact
    (the ``heartbeat_loss`` site) therefore never kills a replica on its
    own — the false-positive resilience the thresholds exist for.

    Defined here (not serving/cluster.py, which re-exports it) so a
    :class:`ServingPlan` can carry the cluster shape without importing
    the cluster module."""
    suspect_after: int = 2
    dead_after: int = 4

    def __post_init__(self):
        if not 1 <= self.suspect_after <= self.dead_after:
            raise ValueError("need 1 <= suspect_after <= dead_after")


@dataclasses.dataclass(frozen=True)
class DurabilityPolicy:
    """Write-ahead-journal knobs (serving/journal.py).

    ``enabled`` turns on journaling for every run built from the plan;
    ``journal_dir`` is where segments, spilled swap images, and the
    plan's own JSON land (the *whole* restart story lives in that one
    directory); ``fsync_boundaries`` is the fsync batching cadence —
    progress records buffer and hit disk every N segment boundaries
    (terminal records always fsync immediately: a SUBMIT/COMPLETE/
    DEAD-LETTER is an acknowledgement); ``segment_bytes`` rotates the
    journal to a fresh segment file once the current one exceeds it.

    Defined here (not serving/journal.py) for the same reason as
    :class:`HealthPolicy`: the plan must carry the knob group without
    importing the machinery."""
    enabled: bool = False
    journal_dir: str = ""
    fsync_boundaries: int = 1
    segment_bytes: int = 1 << 20

    def __post_init__(self):
        if self.enabled and not self.journal_dir:
            raise ValueError("durability enabled without a journal_dir")
        if self.fsync_boundaries < 1:
            raise ValueError("fsync_boundaries must be >= 1")
        if self.segment_bytes < 256:
            raise ValueError("segment_bytes must be >= 256 (a segment "
                             "must fit at least one framed record)")


@dataclasses.dataclass(frozen=True)
class ObservabilityPolicy:
    """Telemetry knobs (serving/observe.py).

    ``enabled`` arms the full layer for runs built from the plan:
    latency histograms, pool/queue gauges, the request-lifecycle
    tracer, and (when ``export_dir`` is set) a Prometheus text export
    plus a JSONL trace written at run end.  Counters stay live either
    way — they back the ``stats()`` views — so disabling telemetry
    only strips the probes that cost something (a disabled probe is
    one attribute lookup against a shared no-op handle).

    ``histogram_buckets`` overrides the default exponential latency
    grid (strictly increasing upper bounds, seconds); empty means the
    default.  ``trace`` turns the tracer off independently for
    metrics-only runs.

    Defined here (not serving/observe.py) for the same reason as
    :class:`HealthPolicy`: the plan carries the knob group without
    importing the machinery."""
    enabled: bool = False
    export_dir: str = ""
    histogram_buckets: tuple = ()
    trace: bool = True

    def __post_init__(self):
        object.__setattr__(self, "histogram_buckets",
                           tuple(float(b)
                                 for b in self.histogram_buckets))
        b = self.histogram_buckets
        if any(x <= 0 for x in b) or list(b) != sorted(set(b)):
            raise ValueError("histogram_buckets must be positive and "
                             f"strictly increasing: {b}")
        if self.export_dir and not self.enabled:
            raise ValueError("export_dir set but observability "
                             "disabled — nothing would be written")


def _filtered(cls, d: dict[str, Any]):
    """Drop-unknown/default-missing constructor for a dataclass — the
    PagedCacheConfig.from_dict forward-compat contract, shared by every
    nested config the plan serializes."""
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One deployment, declaratively.  See the module docstring."""
    arch: str = ""                        # arch config name (informational)
    cache: PagedCacheConfig = dataclasses.field(
        default_factory=PagedCacheConfig)
    prefill_mode: str = "batched"         # "batched" | "serial"
    cache_dtype: str = "bfloat16"         # dtype name (JSON-safe)
    tenants: tuple[TenantConfig, ...] = ()
    n_replicas: int = 1
    health: HealthPolicy = dataclasses.field(default_factory=HealthPolicy)
    durability: DurabilityPolicy = dataclasses.field(
        default_factory=DurabilityPolicy)
    observability: ObservabilityPolicy = dataclasses.field(
        default_factory=ObservabilityPolicy)
    # workload sizing the pool geometry was resolved against
    max_prompt_len: int = 32
    max_new_tokens: int = 16
    # knob -> "tuned" | "relaxed" | "default" | "capped" | "explicit"
    # (or "searched" once the SERVE task moves it off the resolved
    # value); filled by resolve(), empty for hand-assembled plans
    provenance: dict[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.prefill_mode not in ("batched", "serial"):
            raise ValueError(f"prefill_mode={self.prefill_mode!r}")
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")

    # ------------------------------------------------------------ derived
    @property
    def slots(self) -> int:
        return self.cache.max_slots

    @property
    def sharing(self) -> bool:
        """Effective prefix sharing: the serial batch-1 prefill path
        always computes (and would re-store) whole prompts, so sharing
        requires the batched ragged admission path."""
        return self.cache.enable_prefix_sharing \
            and self.prefill_mode == "batched"

    @property
    def cap_tokens(self) -> int:
        """Cache slots one fully generated request occupies (+1: the
        final decode step still writes its token's K/V)."""
        return self.max_prompt_len + self.max_new_tokens + 1

    # ------------------------------------------------------------ resolve
    @classmethod
    def resolve(cls, cfg, *, slots: int, max_prompt_len: int,
                max_new_tokens: int, pool_slots: int | None = None,
                page_size: int | None = None,
                page_size_cap: int | None = None,
                segment_len: int | None = None,
                prefill_mode: str = "batched",
                cache_dtype: str = "bfloat16",
                tenants=(), n_replicas: int = 1,
                health: HealthPolicy | None = None,
                durability: DurabilityPolicy | None = None,
                observability: ObservabilityPolicy | None = None,
                cache_path: str | None = None,
                **cache_overrides: Any) -> "ServingPlan":
        """The one provenance-tracked readback-and-geometry step.

        Consolidates what every bench row used to hand-assemble: read
        the tuned page size (``flash_decode_paged``) and decode-segment
        cadence (``paged_segment``) back from the autotuner's persisted
        cache via :func:`repro.kernels.autotune.tile_readback`, then
        derive the pool geometry — ``blocks = ceil(cap / page_size)``,
        ``n_pages = pool_slots * blocks + 1`` (+1: the scratch page).

        ``page_size``/``segment_len`` override the readback (recorded as
        ``explicit``); ``page_size_cap`` bounds a tuned page size by a
        geometric constraint (e.g. the shared-prefix rows need the pool
        to express the prefix at page granularity — recorded as
        ``capped`` when it bites).  ``pool_slots`` sizes the pool for
        fewer whole lifetimes than ``slots`` (oversubscription).  Extra
        keyword args pass through to :class:`PagedCacheConfig`
        (``prefill_bucket``, ``growth_pages``, ``retain_pages``, ...)
        and are recorded as ``explicit``.
        """
        from repro.kernels import autotune

        cap = max_prompt_len + max_new_tokens + 1
        adt = str(getattr(cfg, "adt", None) or "float32")
        prov: dict[str, str] = {}
        if page_size is None:
            prob = autotune.flash_decode_paged_problem(
                slots, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cap, adt)
            tile, src = autotune.tile_readback("flash_decode_paged", prob,
                                               cache_path=cache_path)
            page_size, prov["page_size"] = int(tile["page_size"]), src
        else:
            page_size, prov["page_size"] = int(page_size), "explicit"
        if page_size_cap is not None and page_size > page_size_cap:
            page_size, prov["page_size"] = int(page_size_cap), "capped"
        if segment_len is None:
            prob = autotune.paged_segment_problem(
                slots, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cap,
                page_size, adt)
            tile, src = autotune.tile_readback("paged_segment", prob,
                                               cache_path=cache_path)
            segment_len, prov["segment_len"] = int(tile["segment_len"]), src
        else:
            segment_len, prov["segment_len"] = int(segment_len), "explicit"
        blocks = -(-cap // page_size)
        pool = slots if pool_slots is None else pool_slots
        cache = PagedCacheConfig(page_size=page_size,
                                 n_pages=pool * blocks + 1,
                                 max_slots=slots, max_blocks=blocks,
                                 segment_len=segment_len,
                                 **cache_overrides)
        for k in cache_overrides:
            prov[k] = "explicit"
        prov["durability"] = "default" if durability is None else "explicit"
        prov["observability"] = \
            "default" if observability is None else "explicit"
        return cls(arch=str(getattr(cfg, "name", "")), cache=cache,
                   prefill_mode=prefill_mode, cache_dtype=cache_dtype,
                   tenants=tuple(tenants or ()), n_replicas=n_replicas,
                   health=health if health is not None else HealthPolicy(),
                   durability=(durability if durability is not None
                               else DurabilityPolicy()),
                   observability=(observability
                                  if observability is not None
                                  else ObservabilityPolicy()),
                   max_prompt_len=max_prompt_len,
                   max_new_tokens=max_new_tokens, provenance=prov)

    # -------------------------------------------------------- (de)serialize
    def to_dict(self) -> dict[str, Any]:
        """JSON-safe dict; the deployable artifact the SERVE task
        emits."""
        return {
            "arch": self.arch,
            "cache": self.cache.to_dict(),
            "prefill_mode": self.prefill_mode,
            "cache_dtype": self.cache_dtype,
            "tenants": [dataclasses.asdict(t) for t in self.tenants],
            "n_replicas": self.n_replicas,
            "health": dataclasses.asdict(self.health),
            "durability": dataclasses.asdict(self.durability),
            "observability": {
                **dataclasses.asdict(self.observability),
                "histogram_buckets":
                    list(self.observability.histogram_buckets)},
            "max_prompt_len": self.max_prompt_len,
            "max_new_tokens": self.max_new_tokens,
            "provenance": dict(self.provenance),
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ServingPlan":
        """Inverse of :meth:`to_dict` under PagedCacheConfig's
        checkpoint-compat contract, applied recursively: unknown keys
        are dropped and missing ones take their defaults at every level
        (plan, cache, tenants, health)."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        if isinstance(kw.get("cache"), dict):
            kw["cache"] = PagedCacheConfig.from_dict(kw["cache"])
        if "tenants" in kw:
            kw["tenants"] = tuple(
                _filtered(TenantConfig, t) if isinstance(t, dict) else t
                for t in kw["tenants"])
        if isinstance(kw.get("health"), dict):
            kw["health"] = _filtered(HealthPolicy, kw["health"])
        if isinstance(kw.get("durability"), dict):
            kw["durability"] = _filtered(DurabilityPolicy,
                                         kw["durability"])
        if isinstance(kw.get("observability"), dict):
            kw["observability"] = _filtered(ObservabilityPolicy,
                                            kw["observability"])
        if "provenance" in kw:
            kw["provenance"] = dict(kw["provenance"])
        return cls(**kw)
