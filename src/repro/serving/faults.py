"""Deterministic fault-injection harness for the serving stack.

MetaML's design-flow thesis is that a flow must survive bad candidate
stages automatically instead of dying on the first anomaly; the serving
engine's analogue is surviving runtime faults — allocation failures,
corrupted host-swap images, poisoned decode numerics, failed dispatches
— without taking down co-resident tenants.  You cannot test that
property without a way to *cause* those faults, and chaos that is not
reproducible is useless in CI.  This module provides the cause:

- A :class:`FaultPlan` is a seed-driven schedule of injections over
  named :data:`SITES`.  Every decision is drawn from a per-site
  ``numpy`` generator keyed on ``(seed, crc32(site))``, and sites count
  their *opportunities* (times the instrumented code path asked),
  so a plan replays bit-exactly whenever the engine's boundary
  schedule replays — which it does: the scheduler is deterministic
  given the request set.
- Injection sites are threaded through the stack as plain
  ``plan.should_fire(site)`` probes: the page allocator
  (``serving/paged_cache.py`` — alloc returns None as if the pool were
  dry), the engine's swap-out path (host image corrupted or dropped
  after its checksum is recorded), the decode segment (a NaN poisoned
  into one slot's logits, in-graph), and the boundary dispatches
  (``plan.gate(site)`` raises :class:`InjectedFault` instead of
  dispatching).
- Plans terminate by construction: every armed site carries a
  ``max_fires`` bound, so a chaos run eventually reverts to fault-free
  behavior — the property the recovery layer's liveness argument
  (``serving/recovery.py``) needs.

The harness is pure host-side bookkeeping (numpy only, no jax) and
costs nothing when no plan is installed: every probe is behind a
``plan is not None`` check in the instrumented modules.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

# Injection sites, in stack order.  Each names one instrumented probe:
#   alloc            PageAllocator.alloc returns None (pool "dry")
#   swap_corrupt     host swap image bytes flipped after checksum capture
#   swap_loss        host swap image dropped entirely
#   decode_poison    NaN added to one slot's logits inside the segment scan
#   dispatch_admit   an admission prefill dispatch raises InjectedFault
#   dispatch_restore a restore scatter dispatch raises InjectedFault
#   dispatch_segment the decode segment dispatch raises InjectedFault
ENGINE_SITES = ("alloc", "swap_corrupt", "swap_loss", "decode_poison",
                "dispatch_admit", "dispatch_restore", "dispatch_segment")
# Replica-level sites, probed by the cluster loop (serving/cluster.py)
# once per live replica per round — never inside a single engine run:
#   replica_crash    the replica's device state is destroyed; its host
#                    loop stops stepping and its heartbeats cease
#   replica_hang     the replica stops stepping indefinitely (heartbeats
#                    cease) but nothing is destroyed — indistinguishable
#                    from a crash to the health model, which is the point
#   heartbeat_loss   one round's heartbeat is dropped while the replica
#                    keeps stepping — exercises false-positive resilience
#                    (a healthy replica marked SUSPECT must recover, and
#                    one fenced DEAD must stay fenced)
REPLICA_SITES = ("replica_crash", "replica_hang", "heartbeat_loss")
# Process-level sites, probed only when a write-ahead journal is armed
# (serving/journal.py) — without one a process death is unrecoverable and
# injecting it would only prove the obvious:
#   wal_torn_write   one journal record reaches disk truncated and the
#                    writer goes dark — the classic crash-mid-write tail
#                    that replay must drop, not die on
#   wal_lost_fsync   one fsync batch silently never reaches disk (page
#                    cache lost at crash); later batches may still land,
#                    so replay sees a record *hole*, not a prefix
#   process_crash    the whole process dies between boundaries: raised
#                    as ProcessCrashed out of EngineRun.step after the
#                    journal drops its unflushed buffer (kill -9
#                    semantics: only fsync'd records survive)
PROCESS_SITES = ("wal_torn_write", "wal_lost_fsync", "process_crash")
SITES = ENGINE_SITES + REPLICA_SITES + PROCESS_SITES
FAULT_SITES = SITES                     # package-level export alias


class InjectedFault(RuntimeError):
    """Raised by ``FaultPlan.gate`` at a dispatch site.  The recovery
    layer catches exactly this type (plus AllocatorError) — real bugs
    keep their own exception types and still fail loudly."""

    def __init__(self, site: str, opportunity: int):
        super().__init__(f"injected fault at site {site!r} "
                         f"(opportunity {opportunity})")
        self.site = site
        self.opportunity = opportunity


class ProcessCrashed(RuntimeError):
    """The ``process_crash`` site fired: the serving process is "dead".
    Deliberately NOT an :class:`InjectedFault` subclass — the in-process
    recovery layer must never catch it (a dead process cannot heal
    itself); it propagates out of ``run()`` and the journal's
    :class:`~repro.serving.journal.RestartRecovery` is the only way
    back."""

    def __init__(self, boundary: int):
        super().__init__(f"injected process crash at boundary {boundary}")
        self.boundary = boundary


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Arming of one site: skip the first ``after`` opportunities, then
    fire with probability ``rate`` per opportunity, at most ``max_fires``
    times.  ``rate=1.0, max_fires=1`` is a scheduled one-shot."""
    site: str
    rate: float = 1.0
    max_fires: int = 1
    after: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites are {SITES}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"{self.site}: rate must be in [0, 1]")
        if self.max_fires < 1:
            raise ValueError(f"{self.site}: max_fires must be >= 1 "
                             f"(plans must terminate)")
        if self.after < 0:
            raise ValueError(f"{self.site}: after must be >= 0")


def _site_rng(seed: int, site: str) -> np.random.Generator:
    """Per-site stream: independent of every other site's draw count,
    so adding a probe at one site never perturbs another's schedule."""
    return np.random.default_rng([int(seed) & 0xFFFFFFFF,
                                  zlib.crc32(site.encode())])


class FaultPlan:
    """A reproducible injection schedule over :data:`SITES`.

    State is per-plan (opportunity/fire counters + a fired log), so a
    fresh plan with the same seed and specs replays identically; reusing
    one plan across engine runs continues its counters — construct a new
    plan per run when you want replay.
    """

    def __init__(self, specs: "list[FaultSpec] | tuple" = (),
                 seed: int = 0):
        self.seed = int(seed)
        self.specs: dict[str, FaultSpec] = {}
        for spec in specs:
            if spec.site in self.specs:
                raise ValueError(f"duplicate spec for site {spec.site!r}")
            self.specs[spec.site] = spec
        self._rng = {site: _site_rng(self.seed, site)
                     for site in self.specs}
        self.opportunities = {site: 0 for site in SITES}
        self.fires = {site: 0 for site in SITES}
        self.log: list[tuple[str, int]] = []   # (site, opportunity idx)
        # optional telemetry taps (serving/observe.py), attached by the
        # engine/cluster when observability is threaded through: a
        # Counter handle labeled (site,) and a (site, opportunity)
        # callable emitting a FAULT trace event.  Both stay outside the
        # draw path, so attaching them never perturbs a schedule.
        self.metrics = None
        self.trace_hook = None

    # ------------------------------------------------------ constructors
    @classmethod
    def at(cls, seed: int = 0, **site_nth: int) -> "FaultPlan":
        """Scheduled one-shots: ``FaultPlan.at(alloc=2, decode_poison=0)``
        fires each named site exactly once, at its nth opportunity
        (0-indexed)."""
        return cls([FaultSpec(site=s, rate=1.0, max_fires=1, after=n)
                    for s, n in site_nth.items()], seed=seed)

    @classmethod
    def seeded(cls, seed: int, sites=SITES, rate: float = 0.1,
               max_fires: int = 2, after: int = 0) -> "FaultPlan":
        """Probabilistic chaos over ``sites``, bounded per site."""
        return cls([FaultSpec(site=s, rate=rate, max_fires=max_fires,
                              after=after) for s in sites], seed=seed)

    # ------------------------------------------------------------ probes
    def should_fire(self, site: str) -> bool:
        """One opportunity at ``site``; True when the plan injects here."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}")
        k = self.opportunities[site]
        self.opportunities[site] = k + 1
        spec = self.specs.get(site)
        if spec is None or k < spec.after \
                or self.fires[site] >= spec.max_fires:
            return False
        # draw only when armed: disarming a site never shifts the stream
        if spec.rate < 1.0 and self._rng[site].random() >= spec.rate:
            return False
        self.fires[site] += 1
        self.log.append((site, k))
        if self.metrics is not None:
            self.metrics.inc(1.0, (site,))
        if self.trace_hook is not None:
            self.trace_hook(site, k)
        return True

    def gate(self, site: str) -> None:
        """Dispatch-site probe: raise instead of returning True."""
        if self.should_fire(site):
            raise InjectedFault(site, self.opportunities[site] - 1)

    @property
    def total_fires(self) -> int:
        return len(self.log)

    def summary(self) -> dict:
        """JSON-safe record of what actually fired (bench/telemetry)."""
        return {"seed": self.seed,
                "specs": {s: dataclasses.asdict(sp)
                          for s, sp in sorted(self.specs.items())},
                "fired": [list(e) for e in self.log],
                "opportunities": {s: n for s, n
                                  in sorted(self.opportunities.items())
                                  if n}}


# ------------------------------------------------- host-image integrity
def image_checksum(*arrays) -> int:
    """CRC32 over the host swap image — recorded at swap-out (before any
    injected corruption), verified once before a restore is planned."""
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


def corrupt_image(arr: np.ndarray) -> np.ndarray:
    """Deterministically flip the first bytes of ``arr`` — the
    swap_corrupt site's payload.  Returns a new array (device_get views
    may be read-only)."""
    buf = bytearray(np.ascontiguousarray(arr).tobytes())
    for i in range(min(8, len(buf))):
        buf[i] ^= 0xFF
    return np.frombuffer(bytes(buf), dtype=arr.dtype).reshape(arr.shape)
