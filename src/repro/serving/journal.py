"""Durable serving: a write-ahead request journal + crash-restart
recovery.

PR 6/7 made the serving stack self-healing *within* a process: boundary
checkpoints, quarantine/retry, swap-image CRCs, replica failover.  All
of that state was process-ephemeral — kill -9 and every in-flight
request was gone.  This module closes the gap with the classic database
answer, shaped to the engine's boundary protocol:

**The journal.**  An append-only write-ahead log in one directory:
CRC-framed records (``<u32 payload_len><u32 crc32><json payload>``)
in segment files (``wal-00000001.seg``, rotated at
``DurabilityPolicy.segment_bytes``), plus spilled swap images
(``img-*.npz``) and the deployment's own ``serving_plan.json`` beside
them — the whole restart story in one directory.  Record types follow
the request lifecycle:

- ``SUBMIT``    request accepted (prompt, tenant, budget) — fsync'd
                immediately: a submit is an acknowledgement
- ``ADMIT``     request (re)took a slot; supersedes any spilled image
- ``CHECKPOINT``  per-boundary committed-token watermarks, batched —
                fsync'd every ``fsync_boundaries`` boundaries
- ``SWAP_IMAGE``  a preempted/quarantined request's host K/V image was
                spilled to disk beside the journal (CRC recorded)
- ``COMPLETE``  terminal success, with the full token stream — fsync'd
                immediately
- ``DEAD_LETTER``  terminal failure: the typed
                :class:`~repro.serving.recovery.RequestFailed` record
                round-trips through the journal

Every payload carries a version (``"v"``); replay skips record types
and versions it does not know, so the format can grow without breaking
old journals.  Replay is **torn-tail tolerant**: a truncated or
CRC-bad record ends replay at the last good record (a conservative
prefix — exactly some crash-consistent state) instead of failing, and
a reopened writer truncates the torn tail before appending.  Replay is
a pure read, hence idempotent: replaying twice equals replaying once.

**Restart recovery.**  :class:`RestartRecovery` rebuilds a
:class:`~repro.serving.engine.PagedServingEngine` (or
:class:`~repro.serving.cluster.ServingCluster` — each replica journals
into its own subdirectory and the streams merge per-request) from
``ServingPlan.from_dict`` on the persisted plan JSON plus journal
replay, then finishes every journaled request through the *existing*
recovery lanes:

- completed requests re-emit their recorded tokens (no recompute);
- requests with a durable spilled image restore through the verified-
  swap-image preempted lane (the image's CRC is checked by
  ``RecoveryManager.verify_swaps`` before its restore is planned, so a
  corrupt file degrades to a restart, never a poisoned pool);
- requests that had unjournaled progress restart from checkpoint 0
  through the pending lane with one retry charged (their K/V died with
  the device state); never-admitted submissions requeue for free.

Greedy decode is deterministic, so all three lanes finish
bit-identical to an uninterrupted run — the property the ``restart``
CI gate (benchmarks/bench_restart.py) enforces end to end with a real
``os._exit`` subprocess crash.

The ``wal_torn_write`` / ``wal_lost_fsync`` / ``process_crash`` fault
sites (:data:`~repro.serving.faults.PROCESS_SITES`) ride the same
seeded opportunity-counted :class:`~repro.serving.faults.FaultPlan` as
every other site, so crash points are bisectable and chaos runs replay
bit-exactly.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import struct
from typing import Any

import numpy as np

from repro.serving.faults import FaultPlan, image_checksum
from repro.serving.observe import NULL_METRIC
from repro.serving.plan import DurabilityPolicy, ServingPlan


def _np_dtype(name: str) -> np.dtype:
    """np.dtype by name, with the ml_dtypes extension types (bfloat16 —
    the default cache dtype) registered on demand."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                # noqa: F401  (registers names)
        return np.dtype(name)


def _save_image(path: str, host_k: np.ndarray, host_v: np.ndarray) -> None:
    """Write a host swap image as raw bytes + dtype/shape sidecar fields
    — np.savez round-trips only native dtypes, and cache images are
    usually bfloat16 (ml_dtypes), which it would silently mangle to
    void."""
    k = np.ascontiguousarray(host_k)
    v = np.ascontiguousarray(host_v)
    np.savez(path,
             k=k.reshape(-1).view(np.uint8),
             v=v.reshape(-1).view(np.uint8),
             k_meta=np.array([str(k.dtype)] + [str(s) for s in k.shape]),
             v_meta=np.array([str(v.dtype)] + [str(s) for s in v.shape]))


def _load_image(path: str) -> tuple[np.ndarray, np.ndarray]:
    with np.load(path) as z:
        out = []
        for name in ("k", "v"):
            meta = [str(m) for m in z[f"{name}_meta"]]
            dt = _np_dtype(meta[0])
            shape = tuple(int(s) for s in meta[1:])
            out.append(z[name].view(dt).reshape(shape))
    return out[0], out[1]

JOURNAL_VERSION = 1
# record types, in lifecycle order (the on-disk "t" field)
SUBMIT = "SUBMIT"
ADMIT = "ADMIT"
CHECKPOINT = "CHECKPOINT"
SWAP_IMAGE = "SWAP_IMAGE"
COMPLETE = "COMPLETE"
DEAD_LETTER = "DEAD_LETTER"
RECORD_TYPES = (SUBMIT, ADMIT, CHECKPOINT, SWAP_IMAGE, COMPLETE,
                DEAD_LETTER)

_HEADER = struct.Struct("<II")          # payload_len, crc32(payload)
_SEG_FMT = "wal-{:08d}.seg"
_SEG_GLOB = "wal-*.seg"
_PLAN_FILE = "serving_plan.json"
_MAX_RECORD = 16 << 20                  # framing sanity bound


class JournalError(RuntimeError):
    """Unrecoverable journal misuse (bad directory, closed writer).
    Never raised for on-disk corruption — that degrades, by design."""


def _crc(payload: bytes) -> int:
    import zlib
    return zlib.crc32(payload) & 0xFFFFFFFF


def _frame(payload: bytes) -> bytes:
    return _HEADER.pack(len(payload), _crc(payload)) + payload


def _scan_segment(path: str) -> tuple[list[dict], int, bool]:
    """Parse one segment file into ``(records, valid_bytes, clean)``.
    ``valid_bytes`` is the offset of the first bad frame (== file size
    when ``clean``) — what a reopened writer truncates the tail to."""
    with open(path, "rb") as f:
        data = f.read()
    records: list[dict] = []
    off = 0
    while off < len(data):
        if off + _HEADER.size > len(data):
            return records, off, False          # torn header
        n, crc = _HEADER.unpack_from(data, off)
        if n > _MAX_RECORD or off + _HEADER.size + n > len(data):
            return records, off, False          # torn/insane payload
        payload = data[off + _HEADER.size:off + _HEADER.size + n]
        if _crc(payload) != crc:
            return records, off, False          # bit rot
        try:
            rec = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            return records, off, False
        if isinstance(rec, dict):
            records.append(rec)
        off += _HEADER.size + n
    return records, off, True


def _segments(journal_dir: str) -> list[str]:
    return sorted(glob.glob(os.path.join(journal_dir, _SEG_GLOB)))


def read_records(journal_dir: str) -> tuple[list[dict], bool]:
    """All readable records in ``journal_dir``, in append order, with a
    flag for whether a torn/corrupt tail was dropped.  Conservative
    prefix: the first bad frame ends the read entirely (everything
    before it is exactly some crash-consistent state; resyncing past
    corruption could interleave states)."""
    out: list[dict] = []
    for seg in _segments(journal_dir):
        records, _, clean = _scan_segment(seg)
        out.extend(records)
        if not clean:
            return out, True
    return out, False


class JournalWriter:
    """Append side of the WAL: CRC framing, segment rotation, fsync
    batching, torn-write/lost-fsync fault probes, and the request-
    lifecycle helpers the engine calls inside its boundary protocol.

    Buffering model: ``append`` stages a framed record in memory;
    ``flush`` writes the batch and fsyncs.  Terminal records (submit /
    complete / dead-letter / spilled image) flush immediately — they
    acknowledge something to the outside world; progress records ride
    the ``fsync_boundaries`` cadence.  ``crash`` abandons the unflushed
    buffer without writing — kill -9 semantics for in-process crash
    simulation (only fsync'd records survive a real one anyway).
    """

    def __init__(self, journal_dir: str, *, segment_bytes: int = 1 << 20,
                 fsync_boundaries: int = 1,
                 faults: FaultPlan | None = None):
        if not journal_dir:
            raise JournalError("journal_dir must be non-empty")
        self.journal_dir = str(journal_dir)
        self.segment_bytes = int(segment_bytes)
        self.fsync_boundaries = max(1, int(fsync_boundaries))
        self._faults = faults
        os.makedirs(self.journal_dir, exist_ok=True)
        self._buf: list[bytes] = []
        self._f = None
        self._closed = False
        self._dead = False              # a torn write went dark
        self.n_appended = 0
        self.n_flushes = 0
        self.n_spilled = 0
        # telemetry handles (serving/observe.py); NULL_METRIC until an
        # EngineRun binds its Observability via bind_metrics() — the
        # plain counters above stay the source for stats() either way
        self._m_appends = NULL_METRIC
        self._m_fsyncs = NULL_METRIC
        self._m_bytes = NULL_METRIC
        self._m_spills = NULL_METRIC
        self._rep = ""
        # rid -> (journaled committed-token count) to skip no-op
        # checkpoint entries, and rid -> spilled image path for GC
        self._ckpt_counts: dict[Any, int] = {}
        self._images: dict[Any, str] = {}
        self._img_seq = 0
        segs = _segments(self.journal_dir)
        if segs:
            # reopen: repair a torn tail (a crashed writer's last frame)
            # so appended records stay framable, then continue appending
            # to the same segment
            last = segs[-1]
            _, valid, clean = _scan_segment(last)
            if not clean:
                with open(last, "r+b") as f:
                    f.truncate(valid)
            self._seg_index = int(os.path.basename(last)[4:12])
            self._seg_written = os.path.getsize(last)
            for img in glob.glob(os.path.join(self.journal_dir,
                                              "img-*.npz")):
                self._img_seq = max(self._img_seq, 1 + int(
                    os.path.basename(img)[4:12]))
        else:
            self._seg_index = 1
            self._seg_written = 0

    @classmethod
    def from_policy(cls, policy: DurabilityPolicy, *,
                    plan: ServingPlan | None = None, subdir: str = "",
                    faults: FaultPlan | None = None) -> "JournalWriter":
        d = (os.path.join(policy.journal_dir, subdir) if subdir
             else policy.journal_dir)
        w = cls(d, segment_bytes=policy.segment_bytes,
                fsync_boundaries=policy.fsync_boundaries, faults=faults)
        if plan is not None:
            w.write_plan(plan.to_dict())
        return w

    def bind_metrics(self, obs) -> None:
        """Attach an Observability's registry handles.  Counters here
        are real only when telemetry is enabled (unlike the serving
        ledgers, the plain ``n_*`` attributes already serve stats());
        idempotent, so re-binding on crash-restart recovery is safe."""
        if not obs.enabled:
            return
        rep = ("replica",)
        self._rep = obs.replica
        self._m_appends = obs.counter(
            "serving_journal_appends_total",
            "WAL records staged for append", rep)
        self._m_fsyncs = obs.counter(
            "serving_journal_fsyncs_total",
            "WAL fsync batches reaching disk", rep)
        self._m_bytes = obs.counter(
            "serving_journal_bytes_total",
            "WAL bytes written (framed, post-batch)", rep)
        self._m_spills = obs.counter(
            "serving_journal_images_spilled_total",
            "host swap images spilled beside the WAL", rep)

    # ------------------------------------------------------------ frames
    def _seg_path(self) -> str:
        return os.path.join(self.journal_dir,
                            _SEG_FMT.format(self._seg_index))

    def _file(self):
        if self._f is None:
            self._f = open(self._seg_path(), "ab")
        return self._f

    def _rotate(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None
        self._seg_index += 1
        self._seg_written = 0

    def append(self, rtype: str, payload: dict, *,
               flush: bool = False) -> None:
        """Stage one record; ``flush=True`` forces it (and everything
        staged before it) to disk with an fsync."""
        if self._closed:
            raise JournalError("append on a closed journal")
        if self._dead:
            return                      # torn write: the WAL went dark
        body = dict(payload)
        body["v"] = JOURNAL_VERSION
        body["t"] = rtype
        frame = _frame(json.dumps(body).encode("utf-8"))
        if self._faults is not None \
                and self._faults.should_fire("wal_torn_write"):
            # the crash-mid-write tail: everything staged before this
            # record lands whole, this record lands truncated, and
            # nothing after it ever reaches disk
            self.flush()
            f = self._file()
            f.write(frame[:max(1, len(frame) // 2)])
            f.flush()
            os.fsync(f.fileno())
            self._dead = True
            return
        self._buf.append(frame)
        self.n_appended += 1
        self._m_appends.inc(1.0, (self._rep,))
        if flush:
            self.flush()

    def flush(self) -> None:
        """Write + fsync the staged batch (the wal_lost_fsync site: a
        fired probe drops the batch on the floor while later batches
        still land — the page-cache reordering hazard, reproduced)."""
        if self._closed or self._dead or not self._buf:
            return
        data = b"".join(self._buf)
        self._buf = []
        if self._faults is not None \
                and self._faults.should_fire("wal_lost_fsync"):
            return
        f = self._file()
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
        self.n_flushes += 1
        self._m_fsyncs.inc(1.0, (self._rep,))
        self._m_bytes.inc(float(len(data)), (self._rep,))
        self._seg_written += len(data)
        if self._seg_written >= self.segment_bytes:
            self._rotate()

    def crash(self) -> None:
        """Simulated kill -9: drop the unflushed buffer, close the fd.
        Everything already fsync'd stays; nothing else does."""
        self._buf = []
        self._closed = True
        if self._f is not None:
            self._f.close()
            self._f = None

    def close(self) -> None:
        if self._closed:
            return
        self.flush()
        self._closed = True
        if self._f is not None:
            self._f.close()
            self._f = None

    # -------------------------------------------------------- plan + GC
    def write_plan(self, plan_dict: dict) -> None:
        """Persist the deployment's ServingPlan JSON beside the journal
        (write-once; the restart side loads it with
        ``ServingPlan.from_dict``)."""
        path = os.path.join(self.journal_dir, _PLAN_FILE)
        if not os.path.exists(path):
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(plan_dict, f, indent=2, sort_keys=True)
            os.replace(tmp, path)

    def adopt_images(self, images: dict[Any, str]) -> None:
        """Seed the image-GC map from a replayed journal (restart
        resume): when a replayed request re-admits or completes, its
        pre-crash spilled image is deleted like a home-grown one."""
        self._images.update(images)

    def _gc_image(self, rid: Any) -> None:
        path = self._images.pop(rid, None)
        if path is not None:
            try:
                os.remove(path)
            except OSError:
                pass

    # ---------------------------------------------- lifecycle shorthands
    def submit(self, req) -> None:
        self.append(SUBMIT, {
            "rid": req.rid, "tenant": req.tenant,
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "arrival": float(req.arrival)}, flush=True)

    def admit(self, req, *, restore: bool) -> None:
        """(Re)admission: supersedes any spilled image (the restore
        consumed it; a fresh admit restarts past it)."""
        self.append(ADMIT, {"rid": req.rid, "restore": bool(restore),
                            "retries": int(req.n_retries)})
        if not restore:
            self._ckpt_counts[req.rid] = 0
        self._gc_image(req.rid)

    def checkpoint(self, boundary: int, running) -> None:
        """One batched watermark record per boundary (only requests
        whose committed count moved), then the fsync cadence."""
        moved = []
        for req in running:
            n = len(req.tokens)
            if self._ckpt_counts.get(req.rid) != n:
                self._ckpt_counts[req.rid] = n
                moved.append([req.rid, n])
        if moved:
            self.append(CHECKPOINT, {"b": int(boundary), "reqs": moved})
        if boundary % self.fsync_boundaries == 0:
            self.flush()

    def spill_image(self, req) -> None:
        """Persist a host swap image beside the journal and record it.
        A lost image (swap_loss fired before the spill) records
        ``file: None`` — replay sends the request down the restart
        lane.  The image file is written *before* its record: a record
        implies the file was at least attempted."""
        sw = req.swap
        if sw is None:
            return
        fname = None
        if sw.host_k is not None and sw.host_v is not None:
            self._gc_image(req.rid)     # an older image is now stale
            fname = f"img-{self._img_seq:08d}.npz"
            self._img_seq += 1
            path = os.path.join(self.journal_dir, fname)
            _save_image(path, np.asarray(sw.host_k),
                        np.asarray(sw.host_v))
            self._images[req.rid] = path
            self.n_spilled += 1
            self._m_spills.inc(1.0, (self._rep,))
        self.append(SWAP_IMAGE, {
            "rid": req.rid, "n_tokens": int(sw.n_tokens),
            "tokens": [int(t) for t in req.tokens],
            "retries": int(req.n_retries), "file": fname,
            "checksum": sw.checksum}, flush=True)

    def complete(self, req) -> None:
        self.append(COMPLETE, {"rid": req.rid,
                               "tokens": [int(t) for t in req.tokens]},
                    flush=True)
        self._gc_image(req.rid)

    def dead_letter(self, record: dict) -> None:
        self.append(DEAD_LETTER, {"record": dict(record)}, flush=True)
        self._gc_image(record.get("rid"))


# ---------------------------------------------------------------- replay
# per-request status lattice; merge across journal streams takes the
# highest rank (greedy determinism makes any crash-consistent state
# resume bit-identical, so rank only encodes "how much work is saved")
_RANK = {"submitted": 0, "running": 1, "swapped": 2, "dead": 3,
         "completed": 4}


@dataclasses.dataclass
class ReplayedRequest:
    """One request's journal-final state."""
    rid: Any
    status: str = "submitted"
    tenant: str = "default"
    prompt: list[int] | None = None
    max_new_tokens: int = 0
    arrival: float = 0.0
    tokens: list[int] = dataclasses.field(default_factory=list)
    n_tokens: int = 0                   # committed watermark (progress)
    retries: int = 0
    image_file: str | None = None       # abs path of the spilled image
    image_checksum: int | None = None
    image_n_tokens: int = 0
    failure: dict | None = None         # DEAD_LETTER record

    def summary(self) -> dict:
        """JSON-safe state for idempotence checks and telemetry."""
        return {"status": self.status, "tokens": list(self.tokens),
                "n_tokens": self.n_tokens, "retries": self.retries,
                "image": os.path.basename(self.image_file)
                if self.image_file else None}


def _apply(state: dict[Any, ReplayedRequest], rec: dict,
           journal_dir: str, counters: dict) -> None:
    v = rec.get("v")
    t = rec.get("t")
    if not isinstance(v, int) or v > JOURNAL_VERSION \
            or t not in RECORD_TYPES:
        counters["skipped"] += 1        # future format: skip, don't die
        return
    if t == CHECKPOINT:
        for rid, n in rec.get("reqs", ()):
            r = state.setdefault(rid, ReplayedRequest(rid=rid))
            r.n_tokens = int(n)
            if r.status in ("submitted", "swapped"):
                # an ADMIT was lost to a dropped fsync batch; progress
                # proves the (re)admission happened and consumed any
                # image — conservative: restart lane
                r.status, r.image_file = "running", None
        return
    if t == DEAD_LETTER:
        d = rec.get("record") or {}
        rid = d.get("rid")
        r = state.setdefault(rid, ReplayedRequest(rid=rid))
        r.status, r.failure, r.image_file = "dead", d, None
        return
    rid = rec.get("rid")
    r = state.setdefault(rid, ReplayedRequest(rid=rid))
    if t == SUBMIT:
        r.tenant = rec.get("tenant", r.tenant)
        r.prompt = [int(x) for x in rec.get("prompt", [])]
        r.max_new_tokens = int(rec.get("max_new_tokens", 0))
        r.arrival = float(rec.get("arrival", 0.0))
        # never downgrades: a duplicate SUBMIT (resume append) keeps
        # whatever progress state the stream already established
    elif t == ADMIT:
        r.retries = int(rec.get("retries", r.retries))
        r.status = "running"
        r.image_file = None             # image consumed or superseded
        if not rec.get("restore", False):
            r.tokens, r.n_tokens = [], 0
    elif t == SWAP_IMAGE:
        r.status = "swapped"
        r.tokens = [int(x) for x in rec.get("tokens", [])]
        r.n_tokens = len(r.tokens)
        r.retries = int(rec.get("retries", r.retries))
        fname = rec.get("file")
        r.image_file = (os.path.join(journal_dir, fname)
                        if fname else None)
        r.image_checksum = rec.get("checksum")
        r.image_n_tokens = int(rec.get("n_tokens", 0))
    elif t == COMPLETE:
        r.status = "completed"
        r.tokens = [int(x) for x in rec.get("tokens", [])]
        r.image_file = None


@dataclasses.dataclass
class JournalReplay:
    """The crash-consistent state a journal directory replays to."""
    journal_dir: str
    requests: dict[Any, ReplayedRequest]
    plan: dict | None                   # serving_plan.json, if present
    truncated: bool                     # a torn/corrupt tail was dropped
    n_records: int
    n_skipped: int                      # unknown type/version records

    def state(self) -> dict:
        """Canonical JSON-safe summary — two replays of the same
        directory are equal iff their ``state()`` dicts are."""
        return {str(rid): self.requests[rid].summary()
                for rid in sorted(self.requests, key=str)}


def replay_journal(journal_dir: str) -> JournalReplay:
    """Replay a journal directory (single-engine: segments at the root;
    cluster: one subdirectory per replica, merged per-request by
    status rank — terminal beats image beats restart; under greedy
    determinism every choice resumes bit-identical, higher rank just
    re-does less work)."""
    journal_dir = str(journal_dir)
    streams = []
    if _segments(journal_dir):
        streams.append(journal_dir)
    for sub in sorted(os.listdir(journal_dir)
                      if os.path.isdir(journal_dir) else []):
        d = os.path.join(journal_dir, sub)
        if os.path.isdir(d) and _segments(d):
            streams.append(d)
    merged: dict[Any, ReplayedRequest] = {}
    truncated = False
    n_records = 0
    counters = {"skipped": 0}
    for d in streams:
        records, torn = read_records(d)
        truncated = truncated or torn
        n_records += len(records)
        state: dict[Any, ReplayedRequest] = {}
        for rec in records:
            _apply(state, rec, d, counters)
        for rid, r in state.items():
            cur = merged.get(rid)
            if cur is None:
                merged[rid] = r
                continue
            # meta can live in one stream (the SUBMIT) and progress in
            # another (post-migration): graft meta onto the winner
            winner, loser = (r, cur) if _RANK[r.status] > \
                _RANK[cur.status] else (cur, r)
            if winner.prompt is None and loser.prompt is not None:
                winner.prompt = loser.prompt
                winner.tenant = loser.tenant
                winner.max_new_tokens = loser.max_new_tokens
                winner.arrival = loser.arrival
            merged[rid] = winner
    plan = None
    plan_path = os.path.join(journal_dir, _PLAN_FILE)
    if os.path.exists(plan_path):
        with open(plan_path) as f:
            plan = json.load(f)
    return JournalReplay(journal_dir=journal_dir, requests=merged,
                         plan=plan, truncated=truncated,
                         n_records=n_records,
                         n_skipped=counters["skipped"])


# ------------------------------------------------------ restart recovery
class RestartRecovery:
    """Cold-restart a serving deployment from its journal directory.

    ``RestartRecovery(journal_dir).resume(model, params)`` loads the
    persisted ServingPlan, rebuilds the engine (or cluster, when the
    plan says ``n_replicas > 1``), reconstructs every journaled request
    into its recovery lane, drives the run to completion, and returns
    the full request set — replayed completions and dead letters
    included — with recovery counters.  The resumed run journals into
    the same directory, so a crash *during* recovery recovers too.
    """

    def __init__(self, journal_dir: str):
        self.journal_dir = str(journal_dir)
        self.replay = replay_journal(self.journal_dir)

    # ------------------------------------------------- request rebuilds
    def _load_image(self, r: ReplayedRequest):
        """Reconstitute a spilled SwapState; None when the file is
        missing or unreadable (the restart lane absorbs it — and a
        readable-but-corrupt image is caught later by verify_swaps'
        CRC, exactly like an in-process swap fault)."""
        from repro.serving.resources import SwapState
        if r.image_file is None:
            return None
        try:
            host_k, host_v = _load_image(r.image_file)
        except Exception:
            return None
        return SwapState(pages=[], n_tokens=r.image_n_tokens, slot=-1,
                         host_k=host_k, host_v=host_v,
                         checksum=r.image_checksum, verified=False)

    def _failure(self, d: dict):
        from repro.serving.recovery import RequestFailed
        kw = dict(rid=d.get("rid"), tenant=d.get("tenant", "default"),
                  reason=d.get("reason", ""),
                  boundary=int(d.get("boundary", 0)),
                  retries=int(d.get("retries", 0)),
                  site=d.get("site", "unknown"),
                  ckpt_tokens=int(d.get("ckpt_tokens", 0)))
        if "replica" in d:
            from repro.serving.cluster import ReplicaLost
            return ReplicaLost(replica=d["replica"], **kw)
        return RequestFailed(**kw)

    def _rebuild(self, policy) -> dict:
        """Classify every replayed request into its lane.  Returns
        terminal/inflight request lists plus counters."""
        from repro.serving.scheduler import Request
        terminal: list = []
        inflight: list = []
        c = {"replayed_completed": 0, "replayed_dead": 0,
             "image_restores": 0, "restarts": 0, "requeued": 0,
             "retries_exhausted": 0, "unrecoverable": 0}
        for rid in sorted(self.replay.requests, key=str):
            r = self.replay.requests[rid]
            if r.status == "dead":
                req = Request(rid=rid,
                              prompt=np.asarray(r.prompt or [],
                                                np.int32),
                              max_new_tokens=r.max_new_tokens,
                              arrival=r.arrival, tenant=r.tenant)
                req.failure = self._failure(r.failure or {})
                req.n_retries = req.failure.retries
                req.t_done = 0.0
                terminal.append(req)
                c["replayed_dead"] += 1
                continue
            if r.prompt is None:
                # the SUBMIT never became durable: the request was
                # never acknowledged, so there is nothing to finish
                c["unrecoverable"] += 1
                continue
            req = Request(rid=rid,
                          prompt=np.asarray(r.prompt, np.int32),
                          max_new_tokens=r.max_new_tokens,
                          arrival=r.arrival, tenant=r.tenant)
            if r.status == "completed":
                req.tokens = list(r.tokens)
                req.t_done = 0.0
                terminal.append(req)
                c["replayed_completed"] += 1
                continue
            req.n_retries = r.retries
            swap = self._load_image(r) if r.status == "swapped" else None
            if swap is not None:
                # verified-swap-image preempted lane: tokens resume at
                # the image's watermark, verify_swaps CRCs it once
                req.swap = swap
                req.tokens = list(r.tokens)
                req.ckpt_tokens = len(req.tokens)
                c["image_restores"] += 1
            elif r.status == "submitted":
                c["requeued"] += 1      # never ran: requeue for free
            else:
                # running at crash (or an unusable image): the device
                # K/V died with the process — restart from ckpt 0,
                # charging a retry iff committed work was lost
                if r.n_tokens > 0 or r.status == "swapped":
                    req.n_retries += 1
                if req.n_retries > policy.max_retries:
                    from repro.serving.recovery import RequestFailed
                    req.failure = RequestFailed(
                        rid=rid, tenant=req.tenant,
                        reason="retries exhausted after process crash",
                        boundary=0, retries=req.n_retries,
                        site="process_crash", ckpt_tokens=0)
                    req.t_done = 0.0
                    terminal.append(req)
                    c["retries_exhausted"] += 1
                    continue
                c["restarts"] += 1
            inflight.append(req)
        return {"terminal": terminal, "inflight": inflight,
                "counters": c}

    # ------------------------------------------------------------ resume
    def resume(self, model, params, *, engine=None,
               faults: FaultPlan | None = None,
               recovery=None) -> dict:
        """Rebuild and run to completion.  ``engine`` short-circuits the
        plan rebuild with an already-compiled engine (its geometry must
        match the journaled plan — tests reuse cached engines this
        way); otherwise the plan JSON beside the journal decides, via
        ``PagedServingEngine.from_plan`` or — when it says
        ``n_replicas > 1`` — ``ServingCluster.from_plan`` with each
        replica journaling into its subdirectory."""
        from repro.serving.engine import EngineRun, PagedServingEngine
        from repro.serving.recovery import RecoveryPolicy
        policy = recovery if recovery is not None else RecoveryPolicy()
        plan = None
        if engine is None:
            if self.replay.plan is None:
                raise JournalError(
                    f"no {_PLAN_FILE} beside the journal in "
                    f"{self.journal_dir!r} and no engine given")
            plan = ServingPlan.from_dict(self.replay.plan)
            # resume journals into THIS directory, whatever path the
            # plan was originally deployed under (the directory may
            # have been copied/moved wholesale)
            plan = dataclasses.replace(
                plan, durability=dataclasses.replace(
                    plan.durability, enabled=True,
                    journal_dir=self.journal_dir))
        built = self._rebuild(policy)
        terminal, inflight = built["terminal"], built["inflight"]
        counters = dict(built["counters"],
                        truncated_tail=self.replay.truncated,
                        n_records=self.replay.n_records)
        if plan is not None and plan.n_replicas > 1:
            stats = self._resume_cluster(model, params, plan, inflight,
                                         terminal, faults, policy)
        else:
            eng = engine if engine is not None \
                else PagedServingEngine.from_plan(model, plan,
                                                  faults=faults,
                                                  recovery=policy)
            pol = plan.durability if plan is not None \
                else DurabilityPolicy(enabled=True,
                                      journal_dir=self.journal_dir)
            journal = JournalWriter.from_policy(pol, plan=eng.plan,
                                                faults=faults)
            journal.adopt_images(
                {r.rid: r.image_file
                 for r in self.replay.requests.values()
                 if r.image_file is not None})
            er = EngineRun(eng, params, faults=faults, recovery=policy,
                           journal=journal)
            lanes = er.obs.counter(
                "serving_journal_replay_requests_total",
                "restart-recovery replayed requests, by lane", ("lane",))
            for lane, n in built["counters"].items():
                if n:
                    lanes.inc(float(n), (lane,))
            for req in inflight:
                er.sched.rm.requeue(req)
            while er.has_work:
                if er.step() == "idle" and er.has_work:
                    er.note_stall()
            stats = er.result()
            terminal.extend(er.sched.finished)
            terminal.extend(er.rec.dead)
            journal.close()
        return {"requests": terminal, "stats": stats,
                "recovered": counters}

    def _resume_cluster(self, model, params, plan, inflight, terminal,
                        faults, policy) -> dict:
        from repro.serving.cluster import ServingCluster
        cluster = ServingCluster.from_plan(model, params, plan,
                                           faults=faults,
                                           recovery=policy)
        for req in inflight:
            target = cluster.front_door.route(req)
            if target is None:
                cluster._cluster_dead_letter(
                    req, "no live replica at restart recovery",
                    site="process_crash", replica="-")
                continue
            target.run.sched.rm.requeue(req)
        stats = cluster.run([])
        terminal.extend(cluster.finished)
        terminal.extend(cluster.dead_lettered)
        cluster.close_journals()
        return stats
