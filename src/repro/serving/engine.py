"""Continuous-batching serving engine over the paged KV cache.

One engine owns: a paged cache pool (serving/paged_cache.py), a scheduler
(serving/scheduler.py), and its jitted entry points —

- ``admit_batch`` (default admission path): *all* requests admitted at a
  segment boundary prefill in one dispatch.  Copy-on-write tail pages are
  forked first, then every admission's *suffix* tokens (the prompt after
  its shared prefix) run through the model with the paged cache attached:
  per-layer suffix K/V scatters into the request's own pages and ragged
  causal attention covers shared prefix + suffix
  (models/layers.py::_paged_attention_prefill /
  kernels/flash_prefill_ragged.py).  Each request's first greedy token is
  picked from its own last valid suffix position in-graph.  Admissions
  that share a prefix compute it once — or zero times, when the prefix
  cache already holds it from an earlier admission.
- ``prefill`` + ``write_pages`` (the PR-3 serial path, kept as the bench
  baseline and for A/B tests): batch-1 prefill of one request into a
  contiguous scratch cache, then a scatter of page-sized chunks into its
  allocated pages.  Serial mode disables prefix sharing — it is the
  measured "before" configuration.
- ``segment``: ``segment_len`` decode steps fused into one
  ``jax.lax.scan`` dispatch over the whole slot batch, with greedy
  sampling, per-slot active masks, and seq_lens advancement carried
  in-graph.

The host loop runs at segment boundaries only, in a fixed order the
resource manager's correctness depends on:

1. retire finished requests (refcounts drop, rows park on the scratch
   page) — this happened at the previous boundary's tail;
2. **grow**: top every running request up to the next segment's page
   coverage (serving/scheduler.py::plan_growth), preempting victims when
   the pool runs dry;
3. **swap out**: ``device_get`` every victim's snapshotted pages to host
   *before any dispatch* — the pages are back on the free list and the
   very next admission may write them;
4. admit: preempted requests **restore first** — trie-rematched prefix
   pages are pure block-table aliasing, the remaining blocks come back
   in one ``_write_pages`` scatter from the host image — then fresh
   requests prefill (batched ragged or serial).  Restores must dispatch
   before fresh prefills: a fresh admission may prefix-share a
   restore-range page, and its attention needs the host image resident;
5. dispatch the next segment, then clear anti-livelock protection on
   every slot that generated through it.

KV state never moves on admission, growth, or completion — only
block-table rows and page refcounts change; it moves exactly twice per
preemption cycle (out to host, back in one scatter).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged_cache import (PagedCacheConfig, TRASH_PAGE,
                                       init_paged_cache, supports_paging)
from repro.serving.resources import DEFAULT_TENANT
from repro.serving.scheduler import ContinuousBatchingScheduler, Request


class PagedServingEngine:
    def __init__(self, model, pcfg: PagedCacheConfig,
                 cache_dtype=jnp.bfloat16, prefill_mode: str = "batched",
                 tenants=None):
        if not supports_paging(model.cfg):
            raise ValueError(f"{model.cfg.name} does not support the "
                             f"paged decode path")
        if prefill_mode not in ("batched", "serial"):
            raise ValueError(f"prefill_mode={prefill_mode!r}")
        self.model = model
        self.pcfg = pcfg
        self.cache_dtype = cache_dtype
        self.prefill_mode = prefill_mode
        self.tenants = list(tenants) if tenants is not None else None
        # prefix sharing needs the ragged suffix prefill: the serial
        # batch-1 path always computes (and would re-store) whole prompts
        self.sharing = pcfg.enable_prefix_sharing and \
            prefill_mode == "batched"
        self._prefill = jax.jit(self._prefill_impl)
        self._write_pages = jax.jit(self._write_pages_impl,
                                    donate_argnums=(0,))
        self._admit_batch = jax.jit(self._admit_batch_impl,
                                    donate_argnums=(1,))
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    # ------------------------------------------------------------ jitted
    def _prefill_impl(self, params, prompt):
        """prompt: (1, S).  Contiguous scratch cache rounded up to whole
        pages so the K/V reshapes to (L, n_pages, page_size, KV, hd)."""
        s = prompt.shape[1]
        cache_len = self.pcfg.pages_for(s) * self.pcfg.page_size
        cache, _ = self.model.init_cache(1, cache_len, self.cache_dtype)
        logits, cache = self.model.prefill(params, {"tokens": prompt},
                                           cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        n_layers, _, _, kv, hd = cache["k"].shape
        shape = (n_layers, -1, self.pcfg.page_size, kv, hd)
        return tok, cache["k"].reshape(shape), cache["v"].reshape(shape)

    def _write_pages_impl(self, blocks, pk, pv, rows):
        """Scatter page chunks (L, n, ps, KV, hd) into physical ``rows``."""
        return {"k_pages": blocks["k_pages"].at[:, rows].set(pk),
                "v_pages": blocks["v_pages"].at[:, rows].set(pv)}

    def _admit_batch_impl(self, params, blocks, tokens, bt, offsets, lens,
                          cow_src, cow_dst):
        """One dispatch for a whole admission boundary.

        tokens: (R, S) suffix tokens padded to the bucket; offsets/lens:
        (R,) shared-prefix offset and valid suffix length per slot (0/0
        for slots not admitted this boundary); cow_src/cow_dst: (R,)
        physical pages to fork before the suffix scatter (TRASH_PAGE
        pairs for slots without a copy-on-write tail).  Returns each
        slot's first greedy token (R, 1) and the updated page pools.
        """
        kp, vp = blocks["k_pages"], blocks["v_pages"]
        # copy-on-write first: a shared tail page's prompt slots must be
        # resident in the request's own copy before this dispatch's
        # scatter appends the remaining suffix to that copy.  No-CoW
        # slots copy scratch->scratch, which the trash page absorbs.
        kp = kp.at[:, cow_dst].set(kp[:, cow_src])
        vp = vp.at[:, cow_dst].set(vp[:, cow_src])
        cache = {"blocks": {"k_pages": kp, "v_pages": vp},
                 "block_tables": bt, "seq_lens": offsets,
                 "prefill_lens": lens}
        logits, cache = self.model.decode_step(params, cache, tokens)
        last = jnp.maximum(lens - 1, 0)
        sel = jnp.take_along_axis(
            logits, last[:, None, None], axis=1)[:, 0]       # (R, V)
        tok = jnp.argmax(sel, axis=-1).astype(jnp.int32)
        return tok[:, None], cache["blocks"]

    def _segment_impl(self, params, cache, tok, active, n_gen, max_new):
        """``segment_len`` decode steps as one fused scan dispatch.

        Inactive slots still run (the batch is dense) but their tokens are
        masked, their seq_lens hold, and their writes land on pages they
        still own or on the scratch page — never on a reclaimed page.
        """
        def step(carry, _):
            cache, tok, active, n_gen = carry
            logits, cache = self.model.decode_step(params, cache, tok)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            nxt = jnp.where(active[:, None], nxt, 0)
            emitted = active
            live = active.astype(jnp.int32)
            n_gen = n_gen + live
            cache = dict(cache, seq_lens=cache["seq_lens"] + live)
            active = active & (n_gen < max_new)
            return (cache, nxt, active, n_gen), (nxt[:, 0], emitted)

        (cache, tok, active, n_gen), (toks, emits) = jax.lax.scan(
            step, (cache, tok, active, n_gen), None,
            length=self.pcfg.segment_len)
        return cache, tok, active, n_gen, toks, emits

    # --------------------------------------------------------- host loop
    def _admit_serial(self, cache, bt, req, params):
        """PR-3 admission: batch-1 prefill + page scatter (no sharing)."""
        pcfg = self.pcfg
        tok1, pk, pv = self._prefill(params,
                                     jnp.asarray(req.prompt[None]))
        n_pp = pcfg.pages_for(req.prompt_len)
        rows = jnp.asarray(np.asarray(req.pages[:n_pp], np.int32))
        cache = dict(cache, blocks=self._write_pages(
            cache["blocks"], pk, pv, rows))
        bt[req.slot] = TRASH_PAGE
        bt[req.slot, :len(req.pages)] = req.pages
        return cache, int(np.asarray(tok1)[0, 0])

    def _admit_batched(self, cache, bt, admitted, params):
        """Batched ragged admission: one dispatch per suffix bucket.

        Rows of a dispatch are the admissions themselves (compact — idle
        slots cost nothing), padded to a power-of-two row count and to
        the bucketized max suffix length, so the compiled-shape space
        stays small while a burst whose prefix already hit the cache
        pays only for its short suffixes.

        Ordering invariant: a sharer must not attend pages its
        same-boundary prefix owner has not written yet.  Within one
        dispatch the per-layer scatter-then-attend ordering covers this
        in-graph; across dispatches, buckets run longest-first, which is
        owner-first whenever the owner's suffix is at least as long as
        the sharer's (the common burst shape).  The one case that
        violates it — a sharer whose *own* suffix outgrows its owner's
        whole suffix (short cached system prompt, long user message) —
        is split into a later *wave* by ``_admission_waves``, so its
        dispatch runs after the owner's.

        Returns {slot: first greedy token}.
        """
        pcfg = self.pcfg
        bucket = max(1, pcfg.prefill_bucket)
        tok_by_slot: dict[int, int] = {}
        n_dispatches = 0
        for req in admitted:
            bt[req.slot] = TRASH_PAGE
            bt[req.slot, :len(req.pages)] = req.pages
        for wave in self._admission_waves(admitted, bucket):
            groups: dict[int, list] = {}
            for req, s_pad in wave:
                groups.setdefault(s_pad, []).append(req)
            for s_pad, reqs in sorted(groups.items(), reverse=True):
                toks, cache = self._dispatch_admissions(cache, bt, reqs,
                                                        s_pad, params)
                tok_by_slot.update(toks)
                n_dispatches += 1
        return cache, tok_by_slot, n_dispatches

    def _admission_waves(self, admitted, bucket):
        """Partition a boundary's admissions (FIFO order) into waves such
        that every same-boundary prefix dependency points to an
        equal-or-larger suffix bucket within the wave — which descending
        bucket order then dispatches first.  A sharer with a *larger*
        bucket than a current-wave owner closes the wave."""
        waves: list[list] = []
        cur: list = []
        cur_writers: dict[int, int] = {}   # page -> writer's bucket
        for req in admitted:
            sfx = req.prompt_len - req.shared_tokens
            s_pad = -(-sfx // bucket) * bucket
            deps = [cur_writers[p] for p in req.pages[:req.shared_pages]
                    if p in cur_writers]
            if any(b < s_pad for b in deps):
                waves.append(cur)
                cur, cur_writers = [], {}
            cur.append((req, s_pad))
            # pages this request's dispatch writes: its fresh suffix +
            # decode pages (shared prefix pages belong to their writer)
            for p in req.pages[req.shared_pages:]:
                cur_writers[p] = s_pad
        if cur:
            waves.append(cur)
        return waves

    def _dispatch_admissions(self, cache, bt, reqs, s_pad, params):
        """One compact jitted dispatch for ``reqs`` at suffix pad
        ``s_pad``; returns ({slot: first token}, cache)."""
        pcfg = self.pcfg
        a = 1
        while a < len(reqs):
            a *= 2
        tokens = np.zeros((a, s_pad), np.int32)
        offs = np.zeros((a,), np.int32)
        lens = np.zeros((a,), np.int32)
        gbt = np.full((a, pcfg.max_blocks), TRASH_PAGE, np.int32)
        cow_src = np.full((a,), TRASH_PAGE, np.int32)
        cow_dst = np.full((a,), TRASH_PAGE, np.int32)
        for i, req in enumerate(reqs):
            suffix = req.prompt[req.shared_tokens:]
            tokens[i, :len(suffix)] = suffix
            offs[i] = req.shared_tokens
            lens[i] = len(suffix)
            gbt[i] = bt[req.slot]
            if req.cow_src is not None:
                cow_src[i] = req.cow_src
                cow_dst[i] = req.cow_dst
        tok1, blocks = self._admit_batch(
            params, cache["blocks"], jnp.asarray(tokens),
            jnp.asarray(gbt), jnp.asarray(offs), jnp.asarray(lens),
            jnp.asarray(cow_src), jnp.asarray(cow_dst))
        tok1 = np.asarray(tok1)
        return ({req.slot: int(tok1[i, 0]) for i, req in enumerate(reqs)},
                dict(cache, blocks=blocks))

    def _swap_out(self, cache, swap) -> None:
        """Pull a preempted request's pages back to host memory.  Must
        run before any subsequent dispatch: the pages are already on the
        free list, and the next admission/restore may overwrite them —
        the device data is only guaranteed intact until then."""
        idx = jnp.asarray(np.asarray(swap.pages, np.int32))
        swap.host_k = np.asarray(cache["blocks"]["k_pages"][:, idx])
        swap.host_v = np.asarray(cache["blocks"]["v_pages"][:, idx])

    def _restore(self, cache, bt, req):
        """One-dispatch restore of a preempted request: blocks below
        ``restore_blocks[0]`` were re-matched from the prefix trie (pure
        aliasing, no data movement); the rest scatter back from the host
        image through the same jitted ``_write_pages`` the serial
        admission uses.  Row counts pad to a power of two (pad rows land
        on the scratch page) so the compiled-shape space stays small."""
        slot = req.slot
        bt[slot] = TRASH_PAGE
        bt[slot, :len(req.pages)] = req.pages
        b0, b1 = req.restore_blocks
        if b1 <= b0:
            return cache, 0
        rows = np.asarray(req.pages[b0:b1], np.int32)
        pk = req.swap.host_k[:, b0:b1]
        pv = req.swap.host_v[:, b0:b1]
        n = len(rows)
        a = 1
        while a < n:
            a *= 2
        if a > n:
            rows = np.concatenate(
                [rows, np.full((a - n,), TRASH_PAGE, np.int32)])
            pad = np.zeros((pk.shape[0], a - n) + pk.shape[2:], pk.dtype)
            pk = np.concatenate([pk, pad], axis=1)
            pv = np.concatenate([pv, pad], axis=1)
        blocks = self._write_pages(cache["blocks"], jnp.asarray(pk),
                                   jnp.asarray(pv), jnp.asarray(rows))
        return dict(cache, blocks=blocks), 1

    def run(self, requests: list[Request], params) -> dict:
        """Serve ``requests`` (honoring their ``arrival`` offsets) to
        completion.  Mutates each request in place (tokens, t_admitted,
        t_done, all relative to engine start) and returns run counters.
        """
        pcfg = self.pcfg
        sched = ContinuousBatchingScheduler(pcfg, sharing=self.sharing,
                                            tenants=self.tenants)
        cache, _ = init_paged_cache(self.model.cfg, pcfg, self.cache_dtype)
        r, m = pcfg.max_slots, pcfg.max_blocks
        bt = np.full((r, m), TRASH_PAGE, np.int32)
        seq_lens = np.zeros((r,), np.int32)
        tok = np.zeros((r, 1), np.int32)
        active = np.zeros((r,), bool)
        n_gen = np.zeros((r,), np.int32)
        max_new = np.ones((r,), np.int32)
        timer = time.perf_counter
        queue = sorted(requests, key=lambda q: q.arrival)
        nxt_arrival = 0
        n_segments = 0
        n_prefill_dispatches = 0
        n_restore_dispatches = 0
        prefill_s = 0.0
        decode_s = 0.0
        no_progress = 0
        t0 = timer()

        def park_slot(slot: int) -> None:
            """Return a vacated slot to the inert state: row on the
            scratch page, no position, no activity.  Shared by
            retirement and preemption — the two ways a slot empties."""
            bt[slot] = TRASH_PAGE
            seq_lens[slot] = 0
            tok[slot] = 0
            active[slot] = False
            n_gen[slot] = 0

        def retire_finished(now: float) -> None:
            for slot, req in list(sched.running.items()):
                if n_gen[slot] >= req.max_new_tokens:
                    req.t_done = now
                    sched.complete(slot)
                    park_slot(slot)

        def start_request(req, first_tok: int, now: float) -> None:
            slot = req.slot
            seq_lens[slot] = req.prompt_len
            tok[slot] = first_tok
            n_gen[slot] = 1
            max_new[slot] = req.max_new_tokens
            active[slot] = req.max_new_tokens > 1
            req.tokens = [int(first_tok)]
            req.t_admitted = now

        while nxt_arrival < len(queue) or sched.has_work:
            now = timer() - t0
            while (nxt_arrival < len(queue)
                   and queue[nxt_arrival].arrival <= now):
                sched.submit(queue[nxt_arrival])
                nxt_arrival += 1
            # growth-on-demand: back the next segment's writes, possibly
            # preempting victims...
            preempted = sched.plan_growth()
            # ...whose pages must reach host memory before any dispatch
            # below can recycle them (their refs are already dropped)
            for req in preempted:
                self._swap_out(cache, req.swap)
                park_slot(req.swap.slot)
            # grown block tables: new pages append to the owned prefix
            for slot, req in sched.running.items():
                bt[slot, :len(req.pages)] = req.pages
            admitted = sched.try_admit()
            fresh = [r for r in admitted if r.swap is None]
            restored = [r for r in admitted if r.swap is not None]
            if admitted:
                t_pf = timer()
                # restores scatter FIRST: a same-boundary fresh admission
                # may trie-share a restore-range page (full-chunk entries
                # are matchable pre-ready by design), so its prefill must
                # only dispatch after the host image is back on device.
                # The reverse order is safe — a restore reads nothing at
                # scatter time; its aliased pages are only attended at
                # the next segment, after every boundary dispatch.
                for req in restored:
                    cache, n_disp = self._restore(cache, bt, req)
                    n_restore_dispatches += n_disp
                    slot = req.slot
                    seq_lens[slot] = req.swap.n_tokens
                    tok[slot] = req.tokens[-1]
                    n_gen[slot] = len(req.tokens)
                    max_new[slot] = req.max_new_tokens
                if fresh and self.prefill_mode == "batched":
                    cache, tok1, n_disp = self._admit_batched(
                        cache, bt, fresh, params)
                    for req in fresh:
                        start_request(req, tok1[req.slot], timer() - t0)
                    n_prefill_dispatches += n_disp
                elif fresh:
                    for req in fresh:
                        cache, first = self._admit_serial(cache, bt, req,
                                                          params)
                        start_request(req, first, timer() - t0)
                        n_prefill_dispatches += 1
                sched.finish_boundary(admitted)
                prefill_s += timer() - t_pf
            retire_finished(timer() - t0)
            if not sched.running:
                if nxt_arrival < len(queue):
                    # the pre-sorted queue's next arrival is the only
                    # possible event while idle: sleep the whole gap
                    wait = queue[nxt_arrival].arrival - (timer() - t0)
                    if wait > 0:
                        time.sleep(wait)
                elif sched.has_work:
                    # queued/preempted requests, nothing running, no
                    # arrivals left: only an admission can make progress
                    # and this boundary produced none — count it toward
                    # the deadlock guard instead of busy-spinning
                    no_progress += 1
                    if no_progress > 256:
                        raise RuntimeError(
                            "serving engine made no progress for 256 "
                            "consecutive boundaries with queued work "
                            "and nothing running: resource-manager "
                            "deadlock (see ResourceManager.stats())")
                continue
            # activity is a pure function of scheduler state: stalled
            # slots sit a segment out (their frozen write slot stays
            # inside pages they own), everyone else runs to max_new
            for slot, req in sched.running.items():
                active[slot] = (not req.stalled) \
                    and n_gen[slot] < max_new[slot]

            t_seg = timer()
            cache = dict(cache, block_tables=jnp.asarray(bt),
                         seq_lens=jnp.asarray(seq_lens))
            cache, tok_d, act_d, gen_d, toks, emits = self._segment(
                params, cache, jnp.asarray(tok), jnp.asarray(active),
                jnp.asarray(n_gen), jnp.asarray(max_new))
            n_segments += 1
            toks = np.asarray(toks)
            decode_s += timer() - t_seg
            emits = np.asarray(emits)
            # np.array (copy): host bookkeeping mutates these in place
            tok = np.array(tok_d)
            active = np.array(act_d)
            n_gen = np.array(gen_d)
            seq_lens = np.array(cache["seq_lens"])
            for slot, req in sched.running.items():
                req.tokens.extend(
                    int(t) for t in toks[emits[:, slot], slot])
            # anti-livelock: surviving one generated segment makes a
            # request preemptable again
            sched.end_segment(slot for slot in sched.running
                              if emits[:, slot].any())
            if emits.any() or admitted or preempted:
                no_progress = 0
            else:
                # unreachable by the liveness argument in resources.py
                # (a stall implies an unprotected victim exists, and
                # protected requests are freshly provisioned to run) —
                # fail loudly rather than spin if a policy bug lands
                no_progress += 1
                if no_progress > 256:
                    raise RuntimeError(
                        "serving engine made no progress for 256 "
                        "consecutive segments: resource-manager "
                        "deadlock (see ResourceManager.stats())")
            retire_finished(timer() - t0)

        return {"n_segments": n_segments,
                "n_admitted": sched.n_admitted,
                "n_finished": len(sched.finished),
                "n_prefill_dispatches": n_prefill_dispatches,
                "n_restore_dispatches": n_restore_dispatches,
                "prefill_s": prefill_s,    # summed admission dispatches
                "decode_s": decode_s,      # summed segment dispatches
                "wall_s": timer() - t0,
                **sched.stats()}


def warmup(engine: PagedServingEngine, params, prompt_len: int,
           max_new_tokens: int, n_requests: int = 1) -> None:
    """Compile prefill + segment outside any timed region.

    One call warms exactly one admission shape: the serial path
    specializes on the prompt's page count, the batched path on the
    padded suffix bucket.  Call once per distinct shape you intend to
    serve (the segment fns are shape-stable across calls); for bursty
    shared-prefix traffic the simplest warmup is running the actual
    workload once untimed, which visits every bucket it will use.
    """
    # a tenant-configured engine rejects unknown tenant names (closed
    # roster), so warmup traffic runs as the first configured tenant
    tenant = (engine.tenants[0].name if engine.tenants
              else DEFAULT_TENANT)
    reqs = [Request(rid=f"warmup{i}",
                    prompt=np.zeros((prompt_len,), np.int32),
                    max_new_tokens=max_new_tokens, tenant=tenant)
            for i in range(n_requests)]
    engine.run(reqs, params)
