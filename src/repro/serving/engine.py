"""Continuous-batching serving engine over the paged KV cache.

One engine owns: a paged cache pool (serving/paged_cache.py), a scheduler
(serving/scheduler.py), and three jitted entry points —

- ``prefill``: batch-1 prefill of one admitted request into a contiguous
  scratch cache sized to a whole number of pages, returning the first
  greedy token and the prompt K/V reshaped into page-sized chunks;
- ``write_pages``: scatter of those chunks into the request's allocated
  physical pages (all layers at once, donated pool);
- ``segment``: ``segment_len`` decode steps fused into one
  ``jax.lax.scan`` dispatch over the whole slot batch, with greedy
  sampling, per-slot active masks, and seq_lens advancement carried
  in-graph.

The host loop runs at segment boundaries only: pull back the tiny control
state (tokens, active, n_gen, seq_lens), retire finished requests (pages
to the free list, block-table row parked on the scratch page), admit
queued ones into the freed slots/pages, and dispatch the next segment.
KV state never moves on admission or eviction — only block-table rows
change — which is what lets one slot batch serve an arrival process whose
requests start and finish at different times (continuous batching) while
paying the contiguous path's per-step cost for the batch, not per
request.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged_cache import (PagedCacheConfig, TRASH_PAGE,
                                       init_paged_cache, supports_paging)
from repro.serving.scheduler import ContinuousBatchingScheduler, Request


class PagedServingEngine:
    def __init__(self, model, pcfg: PagedCacheConfig,
                 cache_dtype=jnp.bfloat16):
        if not supports_paging(model.cfg):
            raise ValueError(f"{model.cfg.name} does not support the "
                             f"paged decode path")
        self.model = model
        self.pcfg = pcfg
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(self._prefill_impl)
        self._write_pages = jax.jit(self._write_pages_impl,
                                    donate_argnums=(0,))
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    # ------------------------------------------------------------ jitted
    def _prefill_impl(self, params, prompt):
        """prompt: (1, S).  Contiguous scratch cache rounded up to whole
        pages so the K/V reshapes to (L, n_pages, page_size, KV, hd)."""
        s = prompt.shape[1]
        cache_len = self.pcfg.pages_for(s) * self.pcfg.page_size
        cache, _ = self.model.init_cache(1, cache_len, self.cache_dtype)
        logits, cache = self.model.prefill(params, {"tokens": prompt},
                                           cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        n_layers, _, _, kv, hd = cache["k"].shape
        shape = (n_layers, -1, self.pcfg.page_size, kv, hd)
        return tok, cache["k"].reshape(shape), cache["v"].reshape(shape)

    def _write_pages_impl(self, blocks, pk, pv, rows):
        """Scatter page chunks (L, n, ps, KV, hd) into physical ``rows``."""
        return {"k_pages": blocks["k_pages"].at[:, rows].set(pk),
                "v_pages": blocks["v_pages"].at[:, rows].set(pv)}

    def _segment_impl(self, params, cache, tok, active, n_gen, max_new):
        """``segment_len`` decode steps as one fused scan dispatch.

        Inactive slots still run (the batch is dense) but their tokens are
        masked, their seq_lens hold, and their writes land on pages they
        still own or on the scratch page — never on a reclaimed page.
        """
        def step(carry, _):
            cache, tok, active, n_gen = carry
            logits, cache = self.model.decode_step(params, cache, tok)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            nxt = jnp.where(active[:, None], nxt, 0)
            emitted = active
            live = active.astype(jnp.int32)
            n_gen = n_gen + live
            cache = dict(cache, seq_lens=cache["seq_lens"] + live)
            active = active & (n_gen < max_new)
            return (cache, nxt, active, n_gen), (nxt[:, 0], emitted)

        (cache, tok, active, n_gen), (toks, emits) = jax.lax.scan(
            step, (cache, tok, active, n_gen), None,
            length=self.pcfg.segment_len)
        return cache, tok, active, n_gen, toks, emits

    # --------------------------------------------------------- host loop
    def run(self, requests: list[Request], params) -> dict:
        """Serve ``requests`` (honoring their ``arrival`` offsets) to
        completion.  Mutates each request in place (tokens, t_admitted,
        t_done, all relative to engine start) and returns run counters.
        """
        pcfg = self.pcfg
        sched = ContinuousBatchingScheduler(pcfg)
        cache, _ = init_paged_cache(self.model.cfg, pcfg, self.cache_dtype)
        r, m = pcfg.max_slots, pcfg.max_blocks
        bt = np.full((r, m), TRASH_PAGE, np.int32)
        seq_lens = np.zeros((r,), np.int32)
        tok = np.zeros((r, 1), np.int32)
        active = np.zeros((r,), bool)
        n_gen = np.zeros((r,), np.int32)
        max_new = np.ones((r,), np.int32)
        timer = time.perf_counter
        queue = sorted(requests, key=lambda q: q.arrival)
        nxt_arrival = 0
        n_segments = 0
        prefill_s = 0.0
        decode_s = 0.0
        t0 = timer()

        def retire_finished(now: float) -> None:
            for slot, req in list(sched.running.items()):
                if n_gen[slot] >= req.max_new_tokens:
                    req.t_done = now
                    sched.complete(slot)
                    bt[slot] = TRASH_PAGE
                    seq_lens[slot] = 0
                    active[slot] = False
                    n_gen[slot] = 0

        while nxt_arrival < len(queue) or sched.has_work:
            now = timer() - t0
            while (nxt_arrival < len(queue)
                   and queue[nxt_arrival].arrival <= now):
                sched.submit(queue[nxt_arrival])
                nxt_arrival += 1
            for req in sched.try_admit():
                t_pf = timer()
                tok1, pk, pv = self._prefill(
                    params, jnp.asarray(req.prompt[None]))
                n_pp = pcfg.pages_for(req.prompt_len)
                rows = jnp.asarray(np.asarray(req.pages[:n_pp], np.int32))
                cache = dict(cache, blocks=self._write_pages(
                    cache["blocks"], pk, pv, rows))
                slot = req.slot
                bt[slot] = TRASH_PAGE
                bt[slot, :len(req.pages)] = req.pages
                seq_lens[slot] = req.prompt_len
                tok[slot] = np.asarray(tok1)[0]
                n_gen[slot] = 1
                max_new[slot] = req.max_new_tokens
                active[slot] = req.max_new_tokens > 1
                req.tokens = [int(tok1[0, 0])]
                req.t_admitted = timer() - t0
                prefill_s += timer() - t_pf
            retire_finished(timer() - t0)
            if not sched.running:
                if nxt_arrival < len(queue):
                    # the pre-sorted queue's next arrival is the only
                    # possible event while idle: sleep the whole gap
                    wait = queue[nxt_arrival].arrival - (timer() - t0)
                    if wait > 0:
                        time.sleep(wait)
                continue

            t_seg = timer()
            cache = dict(cache, block_tables=jnp.asarray(bt),
                         seq_lens=jnp.asarray(seq_lens))
            cache, tok_d, act_d, gen_d, toks, emits = self._segment(
                params, cache, jnp.asarray(tok), jnp.asarray(active),
                jnp.asarray(n_gen), jnp.asarray(max_new))
            n_segments += 1
            toks = np.asarray(toks)
            decode_s += timer() - t_seg
            emits = np.asarray(emits)
            # np.array (copy): host bookkeeping mutates these in place
            tok = np.array(tok_d)
            active = np.array(act_d)
            n_gen = np.array(gen_d)
            seq_lens = np.array(cache["seq_lens"])
            for slot, req in sched.running.items():
                req.tokens.extend(
                    int(t) for t in toks[emits[:, slot], slot])
            retire_finished(timer() - t0)

        return {"n_segments": n_segments,
                "n_admitted": sched.n_admitted,
                "n_finished": len(sched.finished),
                "prefill_s": prefill_s,    # summed batch-1 admissions
                "decode_s": decode_s,      # summed segment dispatches
                "wall_s": timer() - t0}


def warmup(engine: PagedServingEngine, params, prompt_len: int,
           max_new_tokens: int) -> None:
    """Compile prefill + segment outside any timed region.

    One call warms exactly one prompt shape; jitted prefill/page-write
    specialize on the prompt's page count, so call once per distinct
    ``pages_for(prompt_len)`` you intend to serve (the segment fns are
    shape-stable across calls).
    """
    req = Request(rid="warmup",
                  prompt=np.zeros((prompt_len,), np.int32),
                  max_new_tokens=max_new_tokens)
    engine.run([req], params)
