"""Continuous-batching serving engine over the paged KV cache.

One engine owns: a paged cache pool (serving/paged_cache.py), a scheduler
(serving/scheduler.py), and its jitted entry points —

- ``admit_batch`` (default admission path): *all* requests admitted at a
  segment boundary prefill in one dispatch.  Copy-on-write tail pages are
  forked first, then every admission's *suffix* tokens (the prompt after
  its shared prefix) run through the model with the paged cache attached:
  per-layer suffix K/V scatters into the request's own pages and ragged
  causal attention covers shared prefix + suffix
  (models/layers.py::_paged_attention_prefill /
  kernels/flash_prefill_ragged.py).  Each request's first greedy token is
  picked from its own last valid suffix position in-graph.  Admissions
  that share a prefix compute it once — or zero times, when the prefix
  cache already holds it from an earlier admission.
- ``prefill`` + ``write_pages`` (the PR-3 serial path, kept as the bench
  baseline and for A/B tests): batch-1 prefill of one request into a
  contiguous scratch cache, then a scatter of page-sized chunks into its
  allocated pages.  Serial mode disables prefix sharing — it is the
  measured "before" configuration.
- ``segment``: ``segment_len`` decode steps fused into one
  ``jax.lax.scan`` dispatch over the whole slot batch, with greedy
  sampling, per-slot active masks, and seq_lens advancement carried
  in-graph.

The host loop runs at segment boundaries only, in a fixed order the
resource manager's correctness depends on:

1. retire finished requests (refcounts drop, rows park on the scratch
   page) — this happened at the previous boundary's tail;
2. **grow**: top every running request up to the next segment's page
   coverage (serving/scheduler.py::plan_growth), preempting victims when
   the pool runs dry;
3. **swap out**: ``device_get`` every victim's snapshotted pages to host
   *before any dispatch* — the pages are back on the free list and the
   very next admission may write them;
4. admit: preempted requests **restore first** — trie-rematched prefix
   pages are pure block-table aliasing, the remaining blocks come back
   in one ``_write_pages`` scatter from the host image — then fresh
   requests prefill (batched ragged or serial).  Restores must dispatch
   before fresh prefills: a fresh admission may prefix-share a
   restore-range page, and its attention needs the host image resident;
5. dispatch the next segment, then clear anti-livelock protection on
   every slot that generated through it.

KV state never moves on admission, growth, or completion — only
block-table rows and page refcounts change; it moves exactly twice per
preemption cycle (out to host, back in one scatter).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.faults import (FaultPlan, InjectedFault, ProcessCrashed,
                                  corrupt_image, image_checksum)
from repro.serving.observe import Observability, render_summary
from repro.serving.paged_cache import (AllocatorError, PagedCacheConfig,
                                       TRASH_PAGE, init_paged_cache,
                                       supports_paging)
from repro.serving.plan import ServingPlan
from repro.serving.recovery import (EngineStalledError, RecoveryManager,
                                    RecoveryPolicy, diagnostic_snapshot)
from repro.serving.resources import DEFAULT_TENANT
from repro.serving.scheduler import ContinuousBatchingScheduler, Request


class PagedServingEngine:
    def __init__(self, model, pcfg: PagedCacheConfig,
                 cache_dtype=jnp.bfloat16, prefill_mode: str = "batched",
                 tenants=None, faults: FaultPlan | None = None,
                 recovery: RecoveryPolicy | None = None):
        # thin compat layer: the kwargs fold into a ServingPlan, which is
        # the single source of truth every engine now carries
        # (``self.plan``); serving/plan.py is the declarative front door
        plan = ServingPlan(arch=str(getattr(model.cfg, "name", "")),
                           cache=pcfg, prefill_mode=prefill_mode,
                           cache_dtype=jnp.dtype(cache_dtype).name,
                           tenants=tuple(tenants or ()))
        self._init_from_plan(model, plan, faults, recovery)

    @classmethod
    def from_plan(cls, model, plan: ServingPlan, *,
                  faults: FaultPlan | None = None,
                  recovery: RecoveryPolicy | None = None
                  ) -> "PagedServingEngine":
        """Construct from a declarative :class:`ServingPlan` — the
        deployment path for plans the SERVE task searched and emitted as
        JSON (``ServingPlan.from_dict`` then this).  Bit-exact: the
        engine's pool geometry, prefill mode, cache dtype, sharing flag,
        and tenant roster are exactly the plan's."""
        eng = cls.__new__(cls)
        eng._init_from_plan(model, plan, faults, recovery)
        return eng

    def _init_from_plan(self, model, plan: ServingPlan, faults, recovery):
        if not supports_paging(model.cfg):
            raise ValueError(f"{model.cfg.name} does not support the "
                             f"paged decode path")
        self.model = model
        self.plan = plan
        self.pcfg = plan.cache
        self.cache_dtype = jnp.dtype(plan.cache_dtype)
        self.prefill_mode = plan.prefill_mode
        self.tenants = list(plan.tenants) if plan.tenants else None
        # fault/recovery defaults for run(); run(faults=..., recovery=...)
        # overrides per call so one compiled engine serves both the
        # fault-free baseline and its chaos replays
        self.faults = faults
        self.recovery = recovery
        # prefix sharing needs the ragged suffix prefill: the serial
        # batch-1 path always computes (and would re-store) whole prompts
        self.sharing = plan.sharing
        self._prefill = jax.jit(self._prefill_impl)
        self._write_pages = jax.jit(self._write_pages_impl,
                                    donate_argnums=(0,))
        self._admit_batch = jax.jit(self._admit_batch_impl,
                                    donate_argnums=(1,))
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    # ------------------------------------------------------------ jitted
    def _prefill_impl(self, params, prompt):
        """prompt: (1, S).  Contiguous scratch cache rounded up to whole
        pages so the K/V reshapes to (L, n_pages, page_size, KV, hd)."""
        s = prompt.shape[1]
        cache_len = self.pcfg.pages_for(s) * self.pcfg.page_size
        cache, _ = self.model.init_cache(1, cache_len, self.cache_dtype)
        logits, cache = self.model.prefill(params, {"tokens": prompt},
                                           cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        n_layers, _, _, kv, hd = cache["k"].shape
        shape = (n_layers, -1, self.pcfg.page_size, kv, hd)
        return tok, cache["k"].reshape(shape), cache["v"].reshape(shape)

    def _write_pages_impl(self, blocks, pk, pv, rows):
        """Scatter page chunks (L, n, ps, KV, hd) into physical ``rows``."""
        return {"k_pages": blocks["k_pages"].at[:, rows].set(pk),
                "v_pages": blocks["v_pages"].at[:, rows].set(pv)}

    def _admit_batch_impl(self, params, blocks, tokens, bt, offsets, lens,
                          cow_src, cow_dst):
        """One dispatch for a whole admission boundary.

        tokens: (R, S) suffix tokens padded to the bucket; offsets/lens:
        (R,) shared-prefix offset and valid suffix length per slot (0/0
        for slots not admitted this boundary); cow_src/cow_dst: (R,)
        physical pages to fork before the suffix scatter (TRASH_PAGE
        pairs for slots without a copy-on-write tail).  Returns each
        slot's first greedy token (R, 1) and the updated page pools.
        """
        kp, vp = blocks["k_pages"], blocks["v_pages"]
        # copy-on-write first: a shared tail page's prompt slots must be
        # resident in the request's own copy before this dispatch's
        # scatter appends the remaining suffix to that copy.  No-CoW
        # slots copy scratch->scratch, which the trash page absorbs.
        kp = kp.at[:, cow_dst].set(kp[:, cow_src])
        vp = vp.at[:, cow_dst].set(vp[:, cow_src])
        cache = {"blocks": {"k_pages": kp, "v_pages": vp},
                 "block_tables": bt, "seq_lens": offsets,
                 "prefill_lens": lens}
        logits, cache = self.model.decode_step(params, cache, tokens)
        last = jnp.maximum(lens - 1, 0)
        sel = jnp.take_along_axis(
            logits, last[:, None, None], axis=1)[:, 0]       # (R, V)
        tok = jnp.argmax(sel, axis=-1).astype(jnp.int32)
        return tok[:, None], cache["blocks"]

    def _segment_impl(self, params, cache, tok, active, n_gen, max_new,
                      poison):
        """``segment_len`` decode steps as one fused scan dispatch.

        Inactive slots still run (the batch is dense) but their tokens are
        masked, their seq_lens hold, and their writes land on pages they
        still own or on the scratch page — never on a reclaimed page.

        ``poison`` is the decode_poison fault payload: a (R,) float added
        to the first step's logits (all-zero in normal operation, NaN on
        one slot in a chaos run — adding 0.0 is exact, so the fault-free
        graph computes bit-identical tokens).  Whatever the source —
        injection or a real numerics bug — a non-finite last-position
        logit row latches that slot's ``poisoned`` flag in-graph: the
        slot stops emitting and advancing for the rest of the segment
        (its garbage stays beyond the boundary checkpoint's watermark)
        and the host quarantines it at the boundary.  Healthy slots run
        on unaffected.
        """
        def step(carry, _):
            cache, tok, active, n_gen, poison, poisoned = carry
            logits, cache = self.model.decode_step(params, cache, tok)
            logits = logits + poison.astype(logits.dtype)[:, None, None]
            bad = ~jnp.all(jnp.isfinite(logits[:, -1]), axis=-1)
            poisoned = poisoned | bad
            ok = active & ~poisoned
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            nxt = jnp.where(ok[:, None], nxt, 0)
            emitted = ok
            live = ok.astype(jnp.int32)
            n_gen = n_gen + live
            cache = dict(cache, seq_lens=cache["seq_lens"] + live)
            active = active & ~poisoned & (n_gen < max_new)
            poison = jnp.zeros_like(poison)   # first step only
            return (cache, nxt, active, n_gen, poison, poisoned), \
                (nxt[:, 0], emitted)

        poisoned0 = jnp.zeros_like(active)
        (cache, tok, active, n_gen, _, poisoned), (toks, emits) = \
            jax.lax.scan(step,
                         (cache, tok, active, n_gen, poison, poisoned0),
                         None, length=self.pcfg.segment_len)
        return cache, tok, active, n_gen, toks, emits, poisoned

    # --------------------------------------------------------- host loop
    def _admit_serial(self, cache, bt, req, params):
        """PR-3 admission: batch-1 prefill + page scatter (no sharing)."""
        pcfg = self.pcfg
        tok1, pk, pv = self._prefill(params,
                                     jnp.asarray(req.prompt[None]))
        n_pp = pcfg.pages_for(req.prompt_len)
        rows = jnp.asarray(np.asarray(req.pages[:n_pp], np.int32))
        cache = dict(cache, blocks=self._write_pages(
            cache["blocks"], pk, pv, rows))
        bt[req.slot] = TRASH_PAGE
        bt[req.slot, :len(req.pages)] = req.pages
        return cache, int(np.asarray(tok1)[0, 0])

    def _admit_batched(self, cache, bt, admitted, params, faults=None):
        """Batched ragged admission: one dispatch per suffix bucket.

        Rows of a dispatch are the admissions themselves (compact — idle
        slots cost nothing), padded to a power-of-two row count and to
        the bucketized max suffix length, so the compiled-shape space
        stays small while a burst whose prefix already hit the cache
        pays only for its short suffixes.

        Ordering invariant: a sharer must not attend pages its
        same-boundary prefix owner has not written yet.  Within one
        dispatch the per-layer scatter-then-attend ordering covers this
        in-graph; across dispatches, buckets run longest-first, which is
        owner-first whenever the owner's suffix is at least as long as
        the sharer's (the common burst shape).  The one case that
        violates it — a sharer whose *own* suffix outgrows its owner's
        whole suffix (short cached system prompt, long user message) —
        is split into a later *wave* by ``_admission_waves``, so its
        dispatch runs after the owner's.

        Returns ``(cache, {slot: first greedy token}, n_dispatches,
        failed)`` where ``failed`` lists admissions whose dispatch was
        killed by an injected ``dispatch_admit`` fault.  A fault aborts
        the *rest of the boundary* conservatively: later dispatches may
        prefix-share pages the faulted dispatch was supposed to write
        (the wave order guarantees dependencies point strictly to
        earlier dispatches, so everything already dispatched is sound).
        """
        pcfg = self.pcfg
        bucket = max(1, pcfg.prefill_bucket)
        tok_by_slot: dict[int, int] = {}
        n_dispatches = 0
        failed: list = []
        aborted = False
        for req in admitted:
            bt[req.slot] = TRASH_PAGE
            bt[req.slot, :len(req.pages)] = req.pages
        for wave in self._admission_waves(admitted, bucket):
            groups: dict[int, list] = {}
            for req, s_pad in wave:
                groups.setdefault(s_pad, []).append(req)
            for s_pad, reqs in sorted(groups.items(), reverse=True):
                if aborted:
                    failed.extend(reqs)
                    continue
                try:
                    if faults is not None:
                        faults.gate("dispatch_admit")
                    toks, cache = self._dispatch_admissions(
                        cache, bt, reqs, s_pad, params)
                except InjectedFault:
                    aborted = True
                    failed.extend(reqs)
                    continue
                tok_by_slot.update(toks)
                n_dispatches += 1
        return cache, tok_by_slot, n_dispatches, failed

    def _admission_waves(self, admitted, bucket):
        """Partition a boundary's admissions (FIFO order) into waves such
        that every same-boundary prefix dependency points to an
        equal-or-larger suffix bucket within the wave — which descending
        bucket order then dispatches first.  A sharer with a *larger*
        bucket than a current-wave owner closes the wave."""
        waves: list[list] = []
        cur: list = []
        cur_writers: dict[int, int] = {}   # page -> writer's bucket
        for req in admitted:
            sfx = req.prompt_len - req.shared_tokens
            s_pad = -(-sfx // bucket) * bucket
            deps = [cur_writers[p] for p in req.pages[:req.shared_pages]
                    if p in cur_writers]
            if any(b < s_pad for b in deps):
                waves.append(cur)
                cur, cur_writers = [], {}
            cur.append((req, s_pad))
            # pages this request's dispatch writes: its fresh suffix +
            # decode pages (shared prefix pages belong to their writer)
            for p in req.pages[req.shared_pages:]:
                cur_writers[p] = s_pad
        if cur:
            waves.append(cur)
        return waves

    def _dispatch_admissions(self, cache, bt, reqs, s_pad, params):
        """One compact jitted dispatch for ``reqs`` at suffix pad
        ``s_pad``; returns ({slot: first token}, cache)."""
        pcfg = self.pcfg
        a = 1
        while a < len(reqs):
            a *= 2
        tokens = np.zeros((a, s_pad), np.int32)
        offs = np.zeros((a,), np.int32)
        lens = np.zeros((a,), np.int32)
        gbt = np.full((a, pcfg.max_blocks), TRASH_PAGE, np.int32)
        cow_src = np.full((a,), TRASH_PAGE, np.int32)
        cow_dst = np.full((a,), TRASH_PAGE, np.int32)
        for i, req in enumerate(reqs):
            suffix = req.prompt[req.shared_tokens:]
            tokens[i, :len(suffix)] = suffix
            offs[i] = req.shared_tokens
            lens[i] = len(suffix)
            gbt[i] = bt[req.slot]
            if req.cow_src is not None:
                cow_src[i] = req.cow_src
                cow_dst[i] = req.cow_dst
        tok1, blocks = self._admit_batch(
            params, cache["blocks"], jnp.asarray(tokens),
            jnp.asarray(gbt), jnp.asarray(offs), jnp.asarray(lens),
            jnp.asarray(cow_src), jnp.asarray(cow_dst))
        tok1 = np.asarray(tok1)
        return ({req.slot: int(tok1[i, 0]) for i, req in enumerate(reqs)},
                dict(cache, blocks=blocks))

    def _swap_out(self, cache, swap, faults=None) -> None:
        """Pull a preempted request's pages back to host memory.  Must
        run before any subsequent dispatch: the pages are already on the
        free list, and the next admission/restore may overwrite them —
        the device data is only guaranteed intact until then.

        The image's CRC is recorded the moment it lands on host, so any
        later corruption or loss (real, or the swap_corrupt/swap_loss
        fault sites below) is caught by the recovery layer's one-time
        verification before a restore of the image is ever planned."""
        idx = jnp.asarray(np.asarray(swap.pages, np.int32))
        swap.host_k = np.asarray(cache["blocks"]["k_pages"][:, idx])
        swap.host_v = np.asarray(cache["blocks"]["v_pages"][:, idx])
        swap.checksum = image_checksum(swap.host_k, swap.host_v)
        swap.verified = False
        if faults is not None:
            if faults.should_fire("swap_corrupt"):
                swap.host_k = corrupt_image(swap.host_k)
            if faults.should_fire("swap_loss"):
                swap.host_k = swap.host_v = None

    def _restore(self, cache, bt, req):
        """One-dispatch restore of a preempted request: blocks below
        ``restore_blocks[0]`` were re-matched from the prefix trie (pure
        aliasing, no data movement); the rest scatter back from the host
        image through the same jitted ``_write_pages`` the serial
        admission uses.  Row counts pad to a power of two (pad rows land
        on the scratch page) so the compiled-shape space stays small."""
        slot = req.slot
        bt[slot] = TRASH_PAGE
        bt[slot, :len(req.pages)] = req.pages
        b0, b1 = req.restore_blocks
        if b1 <= b0:
            return cache, 0
        rows = np.asarray(req.pages[b0:b1], np.int32)
        pk = req.swap.host_k[:, b0:b1]
        pv = req.swap.host_v[:, b0:b1]
        n = len(rows)
        a = 1
        while a < n:
            a *= 2
        if a > n:
            rows = np.concatenate(
                [rows, np.full((a - n,), TRASH_PAGE, np.int32)])
            pad = np.zeros((pk.shape[0], a - n) + pk.shape[2:], pk.dtype)
            pk = np.concatenate([pk, pad], axis=1)
            pv = np.concatenate([pv, pad], axis=1)
        blocks = self._write_pages(cache["blocks"], jnp.asarray(pk),
                                   jnp.asarray(pv), jnp.asarray(rows))
        return dict(cache, blocks=blocks), 1

    def run(self, requests: list[Request], params, *,
            faults: FaultPlan | None = None,
            recovery: RecoveryPolicy | None = None,
            journal=None, obs: Observability | None = None) -> dict:
        """Serve ``requests`` (honoring their ``arrival`` offsets) to
        completion.  Mutates each request in place (tokens, t_admitted,
        t_done, all relative to engine start) and returns run counters.

        ``faults`` installs a FaultPlan for this run (falling back to the
        engine default) and ``recovery`` overrides the RecoveryPolicy,
        so one compiled engine serves both the fault-free baseline and
        its chaos replays.  With faults armed at any site, run() still
        never raises an injected fault: affected requests roll back to
        their boundary checkpoint, retry with exponential segment
        backoff, and either complete bit-identical to the fault-free run
        or land dead-lettered (``Request.failure``) after bounded
        retries.  The only exception that escapes the loop is
        :class:`EngineStalledError` from the no-progress watchdog.

        With ``plan.durability.enabled`` (or an explicit ``journal``
        writer), every lifecycle transition is journaled inside the
        boundary protocol — and the ``process_crash`` fault site arms:
        :class:`~repro.serving.faults.ProcessCrashed` escapes this loop
        (a dead process cannot heal itself) and
        :class:`~repro.serving.journal.RestartRecovery` finishes the
        work from disk.

        This is a thin wrapper over :class:`EngineRun`: feed arrivals,
        step boundaries, sleep through idle gaps.  A cluster
        (serving/cluster.py) instead drives N EngineRuns round-robin off
        the same compiled engine.
        """
        own_journal = False
        if journal is None and self.plan.durability.enabled:
            from repro.serving.journal import JournalWriter
            journal = JournalWriter.from_policy(
                self.plan.durability, plan=self.plan,
                faults=faults if faults is not None else self.faults)
            own_journal = True
        er = EngineRun(self, params, faults=faults, recovery=recovery,
                       journal=journal, obs=obs)
        queue = sorted(requests, key=lambda q: q.arrival)
        nxt_arrival = 0
        try:
            while nxt_arrival < len(queue) or er.has_work:
                now = er.clock()
                while (nxt_arrival < len(queue)
                       and queue[nxt_arrival].arrival <= now):
                    er.submit(queue[nxt_arrival])
                    nxt_arrival += 1
                if er.step() == "idle":
                    if nxt_arrival < len(queue):
                        # the pre-sorted queue's next arrival is the only
                        # possible event while idle: sleep the whole gap
                        wait = queue[nxt_arrival].arrival - er.clock()
                        if wait > 0:
                            time.sleep(wait)
                    elif er.has_work:
                        # queued/preempted/quarantined requests, nothing
                        # running, no arrivals left: only an admission (or
                        # a backoff expiry) can make progress and this
                        # boundary produced none — count it toward the
                        # watchdog instead of busy-spinning
                        er.note_stall()
            out = er.result()
            pol = er.obs.policy
            if er.obs.enabled and pol is not None and pol.export_dir:
                out["exports"] = er.obs.export(pol.export_dir)
            return out
        finally:
            if own_journal:
                journal.close()     # no-op after a crash() in step()


class EngineRun:
    """One in-flight serving run: every piece of boundary-loop state —
    scheduler, recovery manager, device cache, per-slot host mirrors,
    counters — as attributes, advanced one segment boundary at a time by
    :meth:`step`.

    The split from :class:`PagedServingEngine` (compiled entry points,
    stateless across runs) is what makes replication cheap: a
    :class:`~repro.serving.cluster.ServingCluster` holds ONE engine —
    one set of jitted callables, compiled once — and N EngineRuns, each
    with its own page pool, block tables, tenant ledgers, and prefix
    trie, stepped round-robin.  ``submit`` injects work mid-run (the
    front door routes per arrival) and ``evacuate`` empties the run for
    a graceful drain, with every running request preserved as a verified
    host swap image.
    """

    def __init__(self, engine: PagedServingEngine, params, *,
                 faults: FaultPlan | None = None,
                 recovery: RecoveryPolicy | None = None,
                 clock=None, journal=None,
                 obs: Observability | None = None):
        self.engine = engine
        self.params = params
        pcfg = engine.pcfg
        self.pcfg = pcfg
        self.faults = faults if faults is not None else engine.faults
        policy = recovery if recovery is not None else engine.recovery
        self.policy = policy if policy is not None else RecoveryPolicy()
        if obs is None:
            obs = Observability.from_policy(engine.plan.observability)
        self.obs = obs
        self.tracer = obs.tracer
        self._rep = obs.replica
        self.sched = ContinuousBatchingScheduler.from_plan(
            engine.plan, faults=self.faults, obs=obs)
        self.rec = RecoveryManager(self.policy, self.sched)
        # latency histograms (NULL_METRIC when telemetry is off) + the
        # per-request records result() exports either way
        rep = ("replica",)
        self._h_queue = obs.histogram(
            "serving_queue_wait_seconds",
            "submit (arrival) to admission", rep)
        self._h_ttft = obs.histogram(
            "serving_ttft_seconds",
            "submit (arrival) to first token on device", rep)
        self._h_e2e = obs.histogram(
            "serving_e2e_latency_seconds",
            "submit (arrival) to completion", rep)
        self._h_decode = obs.histogram(
            "serving_decode_seconds_per_token",
            "segment dispatch wall over tokens committed", rep)
        self._h_admit = obs.histogram(
            "serving_admission_batch_seconds",
            "boundary admission wall (restores + prefills)", rep)
        self._g_free = obs.gauge(
            "serving_pool_free_pages", "allocator free pages", rep)
        self._g_held = obs.gauge(
            "serving_pool_held_pages", "allocator held pages", rep)
        self._g_running = obs.gauge(
            "serving_running_requests", "occupied slots", rep)
        self._g_queued = obs.gauge(
            "serving_queued_requests",
            "pending + preempted across tenants", rep)
        self.request_records: list[dict] = []
        # the write-ahead journal (serving/journal.py), when durability
        # is on: lifecycle records are emitted inside the boundary
        # protocol below, and the recovery manager shares the writer so
        # dead letters round-trip through it
        self.journal = journal
        self.rec.journal = journal
        self.cache, _ = init_paged_cache(engine.model.cfg, pcfg,
                                         engine.cache_dtype)
        r, m = pcfg.max_slots, pcfg.max_blocks
        self.bt = np.full((r, m), TRASH_PAGE, np.int32)
        self.seq_lens = np.zeros((r,), np.int32)
        self.tok = np.zeros((r, 1), np.int32)
        self.active = np.zeros((r,), bool)
        self.n_gen = np.zeros((r,), np.int32)
        self.max_new = np.ones((r,), np.int32)
        self.boundary = 0
        self.no_progress = 0
        self.n_segments = 0
        self.n_prefill_dispatches = 0
        self.n_restore_dispatches = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        if clock is None:
            t0 = time.perf_counter()
            clock = lambda: time.perf_counter() - t0   # noqa: E731
        self.clock = clock          # shared by all replicas of a cluster
        if self.faults is not None:
            # telemetry taps: fired AFTER a site's draw, outside the RNG
            # path, so attaching them never perturbs a chaos schedule
            self.faults.metrics = obs.counter(
                "serving_fault_fires_total",
                "injected fault fires, by site", ("site",))
            if self.tracer is not None:
                self.faults.trace_hook = (
                    lambda site, k: self.tracer.event(
                        None, "FAULT", self.boundary, self.clock(),
                        site=site, opportunity=k))
        if journal is not None:
            journal.bind_metrics(obs)

    # ----------------------------------------------------------- frontend
    def submit(self, req: Request) -> None:
        self.sched.submit(req)
        # journal AFTER validation: a rejected submit was never accepted,
        # so there is nothing to make durable
        if self.journal is not None:
            self.journal.submit(req)
        if self.tracer is not None:
            self.tracer.event(req.rid, "SUBMIT", self.boundary,
                              self.clock(), tenant=req.tenant,
                              prompt_len=req.prompt_len,
                              max_new=req.max_new_tokens)

    @property
    def has_work(self) -> bool:
        return self.sched.has_work or self.rec.has_quarantined

    # ------------------------------------------------- slot/request moves
    def _park_slot(self, slot: int) -> None:
        """Return a vacated slot to the inert state: row on the scratch
        page, no position, no activity.  Shared by retirement,
        preemption, and quarantine — every way a slot empties."""
        self.bt[slot] = TRASH_PAGE
        self.seq_lens[slot] = 0
        self.tok[slot] = 0
        self.active[slot] = False
        self.n_gen[slot] = 0

    def _retire_finished(self, now: float) -> None:
        for slot, req in list(self.sched.running.items()):
            if self.n_gen[slot] >= req.max_new_tokens:
                req.t_done = now
                self.sched.complete(slot)
                self._park_slot(slot)
                self._record_done(req)
                if self.journal is not None:
                    self.journal.complete(req)

    def _request_record(self, req: Request) -> dict:
        """Measured per-request latencies, all relative to arrival —
        result()['requests'] is the telemetry source SLO gates read
        instead of recomputing from Request fields."""
        arr = req.arrival
        return {"rid": req.rid, "tenant": req.tenant,
                "queue_wait_s": None if req.t_admitted is None
                else req.t_admitted - arr,
                "ttft_s": None if req.t_first is None
                else req.t_first - arr,
                "e2e_s": None if req.t_done is None
                else req.t_done - arr,
                "n_tokens": len(req.tokens),
                "preemptions": req.n_preempted,
                "retries": req.n_retries,
                "dead": req.failure is not None}

    def _record_done(self, req: Request) -> None:
        rec = self._request_record(req)
        self.request_records.append(rec)
        lab = (self._rep,)
        if rec["queue_wait_s"] is not None:
            self._h_queue.observe(rec["queue_wait_s"], lab)
        if rec["ttft_s"] is not None:
            self._h_ttft.observe(rec["ttft_s"], lab)
        if rec["e2e_s"] is not None:
            self._h_e2e.observe(rec["e2e_s"], lab)
        if self.tracer is not None:
            self.tracer.event(req.rid, "COMPLETE", self.boundary,
                              req.t_done or 0.0,
                              n_tokens=len(req.tokens),
                              preemptions=req.n_preempted,
                              retries=req.n_retries)

    def _start_request(self, req: Request, first_tok: int,
                       now: float) -> None:
        slot = req.slot
        self.seq_lens[slot] = req.prompt_len
        self.tok[slot] = first_tok
        self.n_gen[slot] = 1
        self.max_new[slot] = req.max_new_tokens
        self.active[slot] = req.max_new_tokens > 1
        req.tokens = [int(first_tok)]
        req.t_admitted = now
        if req.t_first is None:
            req.t_first = now

    def note_stall(self) -> None:
        """The deduplicated no-progress watchdog: both the
        nothing-running and the nothing-emitted paths count toward one
        threshold, and tripping it raises a typed error carrying the
        full diagnostic picture instead of a bare message."""
        self.no_progress += 1
        if self.no_progress > self.policy.watchdog_boundaries:
            raise EngineStalledError(
                f"serving engine made no progress for "
                f"{self.policy.watchdog_boundaries} consecutive "
                f"boundaries with work outstanding: resource-"
                f"manager deadlock (diagnostic snapshot attached)",
                diagnostic_snapshot(self.sched, self.rec, self.boundary,
                                    no_progress=self.no_progress,
                                    n_segments=self.n_segments))

    def _vacate(self, req: Request) -> None:
        """Pull a faulted request off its slot: scheduler row freed,
        device row parked on the scratch page."""
        self._park_slot(self.sched.vacate(req))

    def _quarantine_running(self, req: Request, reason: str,
                            site: str) -> None:
        """Roll a faulted running request back to its boundary
        checkpoint: truncate its tokens to the checkpoint, snapshot the
        pages that back it through the ordinary preemption machinery
        (the retry is then a bit-identical one-dispatch restore), vacate
        the slot, and park the request in the quarantine pen for its
        backoff.  Healthy slots are untouched."""
        now = self.clock()
        del req.tokens[req.ckpt_tokens:]
        if req.tokens:
            swap = self.sched.rm.preempt(req, requeue=False)
            self.engine._swap_out(self.cache, swap, self.faults)
            self._vacate(req)
            if self.journal is not None:
                self.journal.spill_image(req)
        else:
            # no committed state to preserve: full restart
            self.sched.rm.release_request(req)
            self._vacate(req)
            self.rec.reset_for_restart(req)
        self.rec.hold(req, reason, self.boundary, now, site=site)

    def _unwind_admission(self, kind: str, req: Request) -> None:
        """A boundary dispatch for this freshly (re)admitted request
        faulted — or a dispatch it could alias did: its K/V never
        materialized on device, so drop the pages and retry.  A failed
        restore keeps its (verified) host image and retries as a
        restore; a failed fresh admission restarts from the prompt."""
        now = self.clock()
        self.sched.rm.release_request(req)
        self._vacate(req)
        if req.swap is not None:
            req.restore_blocks = (0, 0)
        else:
            self.rec.reset_for_restart(req)
        if self.tracer is not None:
            self.tracer.event(req.rid, "ADMIT_FAIL", self.boundary, now,
                              kind=kind)
        self.rec.hold(req, f"injected {kind} dispatch fault",
                      self.boundary, now,
                      site="dispatch_restore" if kind == "restore"
                      else "dispatch_admit")

    # ------------------------------------------------------ one boundary
    def step(self) -> str:
        """Advance one segment boundary (the host-loop order run()'s
        docstring fixes): recovery preflight → growth/swap-out →
        admissions → retire → invariant audit → checkpoint → segment
        dispatch → commit + quarantine + retire.

        Returns ``"ran"`` after a segment dispatch, ``"skipped"`` when an
        injected ``dispatch_segment`` fault dropped it (the boundary
        simply retries), and ``"idle"`` when nothing is running — the
        caller decides whether idleness means sleep (arrivals coming),
        a watchdog tick (:meth:`note_stall` — queued work that cannot
        admit), or that the run is simply drained.
        """
        engine, sched, rec = self.engine, self.sched, self.rec
        faults, clock = self.faults, self.clock
        bt, seq_lens = self.bt, self.seq_lens
        # the process_crash site: probed only when a journal is armed —
        # without one a process death is unrecoverable and injecting it
        # would only prove the obvious.  The journal drops its unflushed
        # buffer (kill -9: only fsync'd records survive) and the
        # exception escapes run() entirely; RestartRecovery is the only
        # way back.
        if self.journal is not None and faults is not None \
                and faults.should_fire("process_crash"):
            self.journal.crash()
            raise ProcessCrashed(self.boundary + 1)
        self.boundary += 1
        boundary = self.boundary
        # recovery preflight: quarantined requests whose backoff
        # expired rejoin their tenant queues; queued host images are
        # checksum-verified exactly once (a corrupted/lost image
        # becomes a restart *before* its restore is planned); under
        # sustained pressure, stale queued work is shed (opt-in)
        rec.release_due(boundary, clock())
        rec.verify_swaps(boundary, clock())
        rec.shed_stalled(boundary, clock())
        # growth-on-demand: back the next segment's writes, possibly
        # preempting victims...
        preempted = sched.plan_growth()
        # ...whose pages must reach host memory before any dispatch
        # below can recycle them (their refs are already dropped)
        for req in preempted:
            engine._swap_out(self.cache, req.swap, faults)
            self._park_slot(req.swap.slot)
            if self.journal is not None:
                # spill the host image beside the journal: a crash from
                # here on restores this request through the verified-
                # swap-image lane instead of restarting it
                self.journal.spill_image(req)
            if self.tracer is not None:
                self.tracer.event(req.rid, "PREEMPT", boundary, clock(),
                                  by=req.preempted_by,
                                  pages=len(req.swap.pages),
                                  n_preempted=req.n_preempted)
        # grown block tables: new pages append to the owned prefix
        for slot, req in sched.running.items():
            bt[slot, :len(req.pages)] = req.pages
        if self.tracer is not None:
            for slot in sorted(sched.running):
                if sched.running[slot].stalled:
                    self.tracer.event(sched.running[slot].rid, "STALL",
                                      boundary, clock())
        admitted = sched.try_admit()
        rec.note_admitted(admitted)
        fresh = [r for r in admitted if r.swap is None]
        restored = [r for r in admitted if r.swap is not None]
        failed_admissions: list = []
        if admitted:
            t_pf = time.perf_counter()
            ok_admitted: list = []
            restore_fault = False
            # restores scatter FIRST: a same-boundary fresh admission
            # may trie-share a restore-range page (full-chunk entries
            # are matchable pre-ready by design), so its prefill must
            # only dispatch after the host image is back on device.
            # The reverse order is safe — a restore reads nothing at
            # scatter time; its aliased pages are only attended at
            # the next segment, after every boundary dispatch.
            for req in restored:
                if restore_fault:
                    failed_admissions.append(("restore", req))
                    continue
                try:
                    if faults is not None:
                        faults.gate("dispatch_restore")
                    self.cache, n_disp = engine._restore(self.cache, bt,
                                                         req)
                except InjectedFault:
                    restore_fault = True
                    failed_admissions.append(("restore", req))
                    continue
                self.n_restore_dispatches += n_disp
                slot = req.slot
                seq_lens[slot] = req.swap.n_tokens
                self.tok[slot] = req.tokens[-1]
                self.n_gen[slot] = len(req.tokens)
                self.max_new[slot] = req.max_new_tokens
                ok_admitted.append(req)
            if restore_fault:
                # conservative: a fresh admission may prefix-share a
                # page in the failed restore's range — without the
                # host image resident, its prefill would attend
                # garbage.  The boundary's remaining admissions all
                # unwind and retry.
                failed_admissions.extend(("admission", r)
                                         for r in fresh)
            elif fresh and engine.prefill_mode == "batched":
                self.cache, tok1, n_disp, failed = engine._admit_batched(
                    self.cache, bt, fresh, self.params, faults)
                for req in fresh:
                    if req.slot in tok1:
                        self._start_request(req, tok1[req.slot], clock())
                        ok_admitted.append(req)
                failed_admissions.extend(("admission", r)
                                         for r in failed)
                self.n_prefill_dispatches += n_disp
            elif fresh:
                admit_fault = False
                for req in fresh:
                    if admit_fault:
                        failed_admissions.append(("admission", req))
                        continue
                    try:
                        if faults is not None:
                            faults.gate("dispatch_admit")
                        self.cache, first = engine._admit_serial(
                            self.cache, bt, req, self.params)
                    except InjectedFault:
                        admit_fault = True
                        failed_admissions.append(("admission", req))
                        continue
                    self._start_request(req, first, clock())
                    self.n_prefill_dispatches += 1
                    ok_admitted.append(req)
            if self.journal is not None:
                # before finish_boundary: it clears req.swap, which is
                # what distinguishes a restore from a fresh admission
                rest_ids = set(map(id, restored))
                for req in ok_admitted:
                    self.journal.admit(req,
                                       restore=id(req) in rest_ids)
            if self.tracer is not None:
                # likewise before finish_boundary, for the restore flag
                rest_ids = set(map(id, restored))
                for req in ok_admitted:
                    self.tracer.event(req.rid, "ADMIT", boundary,
                                      clock(),
                                      restore=id(req) in rest_ids,
                                      slot=req.slot,
                                      pages=len(req.pages or []),
                                      shared_tokens=req.shared_tokens)
            sched.finish_boundary(ok_admitted)
            for kind, req in failed_admissions:
                self._unwind_admission(kind, req)
            dt_pf = time.perf_counter() - t_pf
            self.prefill_s += dt_pf
            self._h_admit.observe(dt_pf, (self._rep,))
        self._retire_finished(clock())
        if not sched.running:
            return "idle"
        if self.policy.check_invariants:
            # opt-in boundary audit of the state the dispatches are
            # about to trust; a violating request is quarantined as
            # a full restart (its pages are suspect) instead of
            # crashing the engine
            bad, _glob = rec.check_invariants(bt, seq_lens)
            for req, why in bad:
                now2 = clock()
                try:
                    sched.rm.release_request(req)
                except AllocatorError:
                    # the ledger itself is inconsistent for this
                    # request; shed what bookkeeping we can
                    req.charged = 0
                    req.pages = None
                self._vacate(req)
                rec.reset_for_restart(req)
                rec.hold(req, f"invariant violation: {why}",
                         boundary, now2, site="invariant")
            if not sched.running:
                return "idle"
        # the boundary checkpoint: everything committed as of this
        # instant is exactly what the device pages back — the
        # watermark every later rollback truncates to
        rec.checkpoint(sched.running.values())
        if self.journal is not None:
            # the durable twin of rec.checkpoint: committed-token
            # watermarks, batched one record per boundary and fsync'd on
            # the plan's cadence
            self.journal.checkpoint(boundary, sched.running.values())
        # activity is a pure function of scheduler state: stalled
        # slots sit a segment out (their frozen write slot stays
        # inside pages they own), everyone else runs to max_new.
        # The feed token is re-derived from committed state, not the
        # segment carry: an inactive slot's carry is masked to 0
        # in-graph, so a slot coming back from a stalled segment
        # would otherwise resume from a zero token (for healthy
        # active slots tokens[-1] IS the carried token, so this is
        # an identity)
        for slot, req in sched.running.items():
            self.active[slot] = (not req.stalled) \
                and self.n_gen[slot] < self.max_new[slot]
            self.tok[slot] = req.tokens[-1]

        poison = np.zeros((self.pcfg.max_slots,), np.float32)
        if faults is not None and faults.should_fire("decode_poison"):
            live = [s for s in sched.running if self.active[s]]
            if live:
                poison[min(live)] = np.nan
        try:
            if faults is not None:
                faults.gate("dispatch_segment")
        except InjectedFault:
            # segment skipped wholesale: no state moved, nothing to
            # roll back — the boundary simply retries.  Bounded by
            # the plan's max_fires.
            rec._c_dispatch_faults.inc(1.0, (rec._rep,))
            return "skipped"
        t_seg = time.perf_counter()
        cache = dict(self.cache, block_tables=jnp.asarray(bt),
                     seq_lens=jnp.asarray(seq_lens))
        cache, tok_d, act_d, gen_d, toks, emits, pois_d = \
            engine._segment(self.params, cache, jnp.asarray(self.tok),
                            jnp.asarray(self.active),
                            jnp.asarray(self.n_gen),
                            jnp.asarray(self.max_new),
                            jnp.asarray(poison))
        self.cache = cache
        self.n_segments += 1
        toks = np.asarray(toks)
        dt_seg = time.perf_counter() - t_seg
        self.decode_s += dt_seg
        emits = np.asarray(emits)
        n_emitted = int(emits.sum())
        if n_emitted:
            self._h_decode.observe(dt_seg / n_emitted, (self._rep,))
        # np.array (copy): host bookkeeping mutates these in place
        self.tok = np.array(tok_d)
        self.active = np.array(act_d)
        self.n_gen = np.array(gen_d)
        self.seq_lens = seq_lens = np.array(cache["seq_lens"])
        poisoned = np.asarray(pois_d)
        for slot, req in sched.running.items():
            req.tokens.extend(
                int(t) for t in toks[emits[:, slot], slot])
        if self.tracer is not None:
            for slot in sorted(sched.running):
                n_em = int(emits[:, slot].sum())
                if n_em:
                    self.tracer.event(sched.running[slot].rid, "SEGMENT",
                                      boundary, clock(), tokens=n_em)
        # anti-livelock: surviving one generated segment makes a
        # request preemptable again
        sched.end_segment(slot for slot in sched.running
                          if emits[:, slot].any())
        # NaN/inf logit guard, before retirement: a poisoned slot
        # stopped emitting in-graph and must never retire garbage —
        # it rolls back to this boundary's checkpoint and retries
        for slot in [s for s in list(sched.running) if poisoned[s]]:
            self._quarantine_running(sched.running[slot],
                                     "non-finite decode logits",
                                     site="decode_poison")
        if emits.any() or admitted or preempted:
            self.no_progress = 0
        else:
            # unreachable by the liveness argument in resources.py
            # (a stall implies an unprotected victim exists, and
            # protected requests are freshly provisioned to run) —
            # fail loudly rather than spin if a policy bug lands
            self.note_stall()
        self._retire_finished(clock())
        if self.obs.enabled:
            alloc = sched.allocator
            lab = (self._rep,)
            self._g_free.set(alloc.n_free, lab)
            self._g_held.set(alloc.n_held, lab)
            self._g_running.set(len(sched.running), lab)
            self._g_queued.set(
                sum(len(st.pending) + len(st.preempted)
                    for st in sched.rm._tenants.values()), lab)
        return "ran"

    # ------------------------------------------------------------- drain
    def evacuate(self) -> list[Request]:
        """Empty the run for a graceful drain: preempt every running
        request through the ordinary host-swap machinery (the device is
        healthy, so every image is captured and CRC'd), then hand back
        everything queued and quarantined.  After this the run holds no
        requests and its pool is back to free + retention pins; the
        caller (cluster drain/rolling restart) re-routes the returned
        requests to other replicas."""
        out: list[Request] = []
        for slot in sorted(self.sched.running):
            req = self.sched.running[slot]
            swap = self.sched.rm.preempt(req, requeue=False)
            self.engine._swap_out(self.cache, swap, self.faults)
            req.n_preempted += 1
            self._vacate(req)
            if self.journal is not None:
                self.journal.spill_image(req)
            out.append(req)
        out.extend(self.sched.rm.drain_queued())
        out.extend(self.rec.drain_quarantined())
        return out

    # ------------------------------------------------------------ result
    def result(self) -> dict:
        out = {"n_segments": self.n_segments,
               "n_admitted": self.sched.n_admitted,
               "n_finished": len(self.sched.finished),
               "n_dead_lettered": len(self.rec.dead),
               "n_prefill_dispatches": self.n_prefill_dispatches,
               "n_restore_dispatches": self.n_restore_dispatches,
               "prefill_s": self.prefill_s,   # summed admission work
               "decode_s": self.decode_s,     # summed segment dispatches
               "wall_s": self.clock(),
               "recovery": self.rec.stats(),
               # measured per-request latency records (dead letters
               # included) + the registry roll-up: SLO gates and the
               # traffic replay feature vector read from here instead of
               # re-deriving from Request fields
               "requests": [dict(r) for r in self.request_records]
               + [self._request_record(r) for r in self.rec.dead],
               "metrics": render_summary(self.obs.registry),
               **self.sched.stats()}
        if self.faults is not None:
            out["faults"] = self.faults.summary()
        if self.journal is not None:
            out["journal"] = {"n_appended": self.journal.n_appended,
                              "n_flushes": self.journal.n_flushes,
                              "n_spilled": self.journal.n_spilled}
        return out


def warmup(engine: PagedServingEngine, params, prompt_len: int,
           max_new_tokens: int, n_requests: int = 1) -> None:
    """Compile prefill + segment outside any timed region.

    One call warms exactly one admission shape: the serial path
    specializes on the prompt's page count, the batched path on the
    padded suffix bucket.  Call once per distinct shape you intend to
    serve (the segment fns are shape-stable across calls); for bursty
    shared-prefix traffic the simplest warmup is running the actual
    workload once untimed, which visits every bucket it will use.
    """
    # a tenant-configured engine rejects unknown tenant names (closed
    # roster), so warmup traffic runs as the first configured tenant
    tenant = (engine.tenants[0].name if engine.tenants
              else DEFAULT_TENANT)
    reqs = [Request(rid=f"warmup{i}",
                    prompt=np.zeros((prompt_len,), np.int32),
                    max_new_tokens=max_new_tokens, tenant=tenant)
            for i in range(n_requests)]
    engine.run(reqs, params)
