"""Continuous-batching scheduler over the quota-aware resource manager.

The engine (serving/engine.py) decodes in fixed-length scan *segments*;
this scheduler is the host-side brain that runs at segment boundaries,
with every page/quota/victim decision delegated to
:class:`~repro.serving.resources.ResourceManager`:

- ``submit`` queues a request onto its tenant's FIFO queue (validated
  once against pool capacity and the tenant's page budget);
- ``plan_growth`` (first at each boundary) tops every running request up
  to the next segment's page coverage — growth-on-demand instead of the
  old whole-lifetime reservation.  A dry pool first evicts the prefix
  cache's retention pins, then **preempts** a victim (swap its pages to
  host, recycle them); a quota-dry tenant can only preempt its own
  requests.  A grower with no admissible victim *stalls* for one segment
  (inactive, its frozen write slot still backed by pages it owns) and
  retries at the next boundary.
- ``try_admit`` runs deficit-round-robin across tenant queues — restores
  ahead of fresh admissions, FIFO within a tenant (no overtaking), each
  admission billed its *marginal* fresh pages (prefix-shared pages are
  free).  Fresh admissions map the longest resident prompt prefix from
  the trie exactly as before; preempted requests re-admit with a
  prefix-trie re-match plus a host swap-in plan for the remainder.
  Admission never preempts — only a running request's growth does — so
  a queued burst cannot evict in-flight work.
- ``complete`` retires a finished request; all page accounting flows
  through the allocator's refcounts via ``ResourceManager.release_request``
  (the PR-3/4 scheduler kept a parallel whole-lifetime page count that
  growth-on-demand made wrong; the refcounts are now the only truth).
- ``end_segment`` clears the anti-livelock ``protected`` flag on every
  request that generated through the segment — from then on it is a
  preemption candidate again.

The scheduler moves no device data: the engine executes the swap
(``device_get`` before any same-boundary dispatch) and the one-dispatch
restore scatter, in the order run() documents.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import numpy as np

from repro.serving.paged_cache import PagedCacheConfig
from repro.serving.resources import (DEFAULT_TENANT, AdmissionPlan,
                                     ResourceManager, SwapState,
                                     TenantConfig)


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""
    rid: Any
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival: float = 0.0               # offset from engine start (bench)
    tenant: str = DEFAULT_TENANT

    # runtime state, owned by the scheduler/resource manager/engine
    slot: int | None = None
    pages: list[int] | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    t_admitted: float | None = None
    t_first: float | None = None       # first token on device (TTFT)
    t_done: float | None = None
    # prefix-sharing state: tokens [0, shared_tokens) are served by mapped
    # pages; the engine prefills only [shared_tokens, prompt_len).
    shared_tokens: int = 0
    shared_pages: int = 0              # full pages mapped from the trie
    cow_src: int | None = None         # tail page to copy-on-write from
    cow_dst: int | None = None         # the request's own tail page
    # resource-manager state
    charged: int = 0                   # fresh pages billed to the tenant
    admit_seq: int = -1                # admission order (victim policy)
    protected: bool = False            # anti-livelock: no preemption yet
    stalled: bool = False              # growth denied; inactive one segment
    swap: SwapState | None = None      # host image while preempted
    n_preempted: int = 0               # times this request was swapped out
    preempted_by: Any = None           # rid of the grower that evicted us
    # host-image block range [b0, b1) the engine scatters on restore (the
    # blocks before b0 were re-matched from the prefix trie)
    restore_blocks: tuple[int, int] = (0, 0)
    # recovery state (serving/recovery.py): the boundary checkpoint every
    # rollback targets, the bounded retry count, and — for dead-lettered
    # requests — the typed RequestFailed terminal record
    ckpt_tokens: int = 0               # committed tokens at last boundary
    n_retries: int = 0                 # quarantine cycles so far
    failure: Any = None                # RequestFailed when dead-lettered

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.t_done is not None


class ContinuousBatchingScheduler:
    @classmethod
    def from_plan(cls, plan, *, faults=None, obs=None
                  ) -> "ContinuousBatchingScheduler":
        """Construct from a :class:`~repro.serving.plan.ServingPlan` —
        cache geometry, effective sharing flag, and tenant roster all
        come from the one declarative artifact."""
        return cls(plan.cache, sharing=plan.sharing,
                   tenants=plan.tenants or None, faults=faults,
                   obs=obs)

    def __init__(self, pcfg: PagedCacheConfig, *,
                 sharing: bool | None = None,
                 tenants: Iterable[TenantConfig] | None = None,
                 faults=None, obs=None):
        self.pcfg = pcfg
        self.rm = ResourceManager(pcfg, tenants, sharing=sharing,
                                  faults=faults, obs=obs)
        self.obs = self.rm.obs
        self._rep = self.obs.replica
        self._c_blocked = self.obs.counter(
            "serving_admission_blocked_total",
            "admission attempts held back, by reason",
            ("replica", "reason"))
        # gauges only exist when telemetry is on (NULL_METRIC otherwise)
        self._g_deficit = self.obs.gauge(
            "serving_tenant_deficit_pages",
            "DRR credit per tenant at boundary end",
            ("replica", "tenant"))
        # aliases: the allocator/trie are owned by the resource manager
        self.allocator = self.rm.allocator
        self.sharing = self.rm.sharing
        self.prefix_cache = self.rm.prefix_cache
        self.running: dict[int, Request] = {}       # slot -> request
        self.free_slots = sorted(range(pcfg.max_slots))
        self.finished: list[Request] = []
        self.n_admitted = 0

    @property
    def pending(self) -> list[Request]:
        """Queued requests across all tenants (restores first)."""
        return self.rm.queued()

    @property
    def has_work(self) -> bool:
        return bool(self.rm.has_queued or self.running)

    def submit(self, req: Request) -> None:
        self.rm.validate(req)
        self.rm.enqueue(req)

    # ------------------------------------------------- growth + preemption
    def plan_growth(self) -> list[Request]:
        """Top every running request up to next-segment page coverage,
        preempting victims when allocations bounce.  Oldest admissions
        grow first (they are closest to finishing — freeing everything).
        Returns the preempted requests, whose ``swap`` snapshots the
        engine must ``device_get`` before its next dispatch."""
        preempted: list[Request] = []
        for req in sorted(self.running.values(), key=lambda r: r.admit_seq):
            if req.swap is not None:
                continue                  # preempted earlier this boundary
            need = self.rm.growth_need(req)
            if need == 0:
                req.stalled = False
                continue
            while True:
                pages, reason = self.rm.grow(req, need)
                if pages is not None:
                    req.stalled = False
                    break
                if reason == "pool":
                    short = need - self.rm.allocator.n_free
                    if self.rm.release_pressure(short) > 0:
                        continue          # pins yielded: retry the alloc
                    victim = self.rm.pick_victim(self.running.values(),
                                                 exclude=req)
                else:                     # quota: the tenant evicts itself
                    victim = self.rm.pick_victim(self.running.values(),
                                                 exclude=req,
                                                 tenant=req.tenant)
                if victim is None:
                    req.stalled = True    # safe: coverage >= frozen slot
                    break
                self._preempt(victim, grower=req)
                preempted.append(victim)
        return preempted

    def _preempt(self, victim: Request,
                 grower: Request | None = None) -> None:
        self.rm.preempt(victim)           # snapshot + release + requeue
        victim.n_preempted += 1
        victim.preempted_by = grower.rid if grower is not None else None
        self.vacate(victim)

    def vacate(self, req: Request) -> int:
        """Free a request's slot without completing it — the
        scheduler-side half of emptying a slot, shared by preemption,
        fault quarantine, and drain evacuation (the engine parks the
        device row on the scratch page).  Returns the freed slot."""
        slot = req.slot
        del self.running[slot]
        self.free_slots.append(slot)
        self.free_slots.sort()
        req.slot = None
        req.stalled = False
        req.protected = False
        return slot

    # ----------------------------------------------------------- admission
    def try_admit(self) -> list[Request]:
        """Deficit-round-robin admission across tenant queues.

        Each round every tenant with queued work accrues
        ``weight x quantum`` pages of deficit and admits queue heads
        while the deficit covers their marginal (fresh-page) cost and a
        slot + pages + quota headroom exist.  A blocked head blocks its
        tenant's queue (no overtaking); rounds continue while someone is
        deficit-blocked, bounded by ``ResourceManager.max_rounds``.
        Restored requests come back ``swap is not None`` — the engine
        runs their swap-in scatter instead of a prefill."""
        admitted: list[Request] = []
        if not self.free_slots or not self.rm.has_queued:
            return admitted
        order = self.rm.rotation()
        for _ in range(self.rm.max_rounds()):
            any_admit = False
            deficit_blocked = False
            for st in order:
                if not st.has_queued:
                    st.deficit = 0.0      # classic DRR: credit dies idle
                    continue
                # cap at the costliest possible admission: a head blocked
                # on pages for many boundaries must not bank unbounded
                # credit and later lock out every other tenant
                st.deficit = min(st.deficit + st.cfg.weight
                                 * self.rm.quantum,
                                 float(self.pcfg.allocatable_pages))
                while self.free_slots and st.has_queued:
                    req = st.head()
                    plan = self.rm.plan_admission(req)
                    if not isinstance(plan, AdmissionPlan):
                        # quota/pool: head holds the line
                        self._c_blocked.inc(1.0, (self._rep, plan))
                        break
                    if plan.cost > st.deficit:
                        deficit_blocked = True
                        self._c_blocked.inc(1.0, (self._rep, "deficit"))
                        break
                    if not self.rm.commit_admission(plan):
                        break             # optimistic pins freed nothing
                    st.pop_head()
                    st.deficit -= plan.cost
                    req.restore_blocks = plan.restore_blocks
                    req.slot = self.free_slots.pop(0)
                    self.running[req.slot] = req
                    self.n_admitted += 1
                    admitted.append(req)
                    any_admit = True
                if not st.has_queued:
                    st.deficit = 0.0
            if not any_admit and not deficit_blocked:
                break
        return admitted

    def finish_boundary(self, admitted: list[Request]) -> None:
        """Called by the engine after the boundary dispatches: CoW copies
        and restore scatters have landed (drop source pins, drop host
        images) and the admitted requests' K/V is on device (trie entries
        become ready)."""
        for req in admitted:
            if req.cow_src is not None:
                self.rm.allocator.release([req.cow_src])
                req.cow_src = None
            req.swap = None               # host image no longer needed
        if self.prefix_cache is not None:
            self.prefix_cache.mark_ready()
        if self.obs.enabled:
            for name, st in self.rm._tenants.items():
                self._g_deficit.set(st.deficit, (self._rep, name))

    def end_segment(self, generated_slots: Iterable[int]) -> None:
        """Anti-livelock bookkeeping: a request that generated through a
        full segment loses its protection and becomes preemptable."""
        for slot in generated_slots:
            req = self.running.get(slot)
            if req is not None:
                req.protected = False

    def complete(self, slot: int) -> Request:
        """Retire the request in ``slot``.  All page bookkeeping is the
        allocator's refcounts (ResourceManager.release_request): pages
        whose last reference dies are free for the very next admission."""
        req = self.running.pop(slot)
        self.rm.release_request(req)
        req.slot = None
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.finished.append(req)
        return req

    def stats(self) -> dict[str, Any]:
        """Resource/prefix counters for benches and telemetry."""
        return self.rm.stats()
