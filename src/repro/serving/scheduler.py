"""Continuous-batching scheduler: FIFO admission gated on free pages.

The engine (serving/engine.py) decodes in fixed-length scan *segments*;
this scheduler is the host-side brain that runs at segment boundaries:

- ``submit`` queues a request (validated against pool capacity once);
- ``try_admit`` moves queued requests into free batch slots while the
  page allocator can cover each request's whole lifetime
  (``prompt + max_new + 1`` tokens) — all-or-nothing, FIFO order (no
  overtaking: a small request never starves a big head-of-line one);
- ``complete`` retires a finished request, returning its pages to the
  free list — the very next ``try_admit`` can hand them to a queued
  request, which is the continuous-batching memory win over the
  contiguous cache's drain-the-whole-batch behavior.

Growth-on-demand admission (admit on prompt pages only, allocate decode
pages as generation proceeds, preempt on pool exhaustion) packs tighter
but needs in-flight preemption; it is a ROADMAP open item.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.serving.paged_cache import PageAllocator, PagedCacheConfig


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""
    rid: Any
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival: float = 0.0               # offset from engine start (bench)

    # runtime state, owned by the scheduler/engine
    slot: int | None = None
    pages: list[int] | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    t_admitted: float | None = None
    t_done: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.t_done is not None


class ContinuousBatchingScheduler:
    def __init__(self, pcfg: PagedCacheConfig):
        self.pcfg = pcfg
        self.allocator = PageAllocator(pcfg.n_pages)
        self.pending: deque[Request] = deque()
        self.running: dict[int, Request] = {}       # slot -> request
        self.free_slots = sorted(range(pcfg.max_slots))
        self.finished: list[Request] = []
        self.n_admitted = 0

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.running)

    def submit(self, req: Request) -> None:
        self.pcfg.validate_request(req.prompt_len, req.max_new_tokens)
        self.pending.append(req)

    def try_admit(self) -> list[Request]:
        """Admit queued requests while a slot and enough pages are free."""
        admitted = []
        while self.pending and self.free_slots:
            req = self.pending[0]
            need = self.pcfg.pages_for(req.prompt_len
                                       + req.max_new_tokens + 1)
            pages = self.allocator.alloc(need)
            if pages is None:
                break                     # FIFO: wait for pages to free up
            self.pending.popleft()
            req.pages = pages
            req.slot = self.free_slots.pop(0)
            self.running[req.slot] = req
            self.n_admitted += 1
            admitted.append(req)
        return admitted

    def complete(self, slot: int) -> Request:
        """Retire the request in ``slot``; its pages are free for the next
        admission immediately."""
        req = self.running.pop(slot)
        self.allocator.release(req.pages)
        req.pages = None
        req.slot = None
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.finished.append(req)
        return req
