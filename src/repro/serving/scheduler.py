"""Continuous-batching scheduler: FIFO admission gated on free pages,
with prefix-sharing admission against the page-chunk trie.

The engine (serving/engine.py) decodes in fixed-length scan *segments*;
this scheduler is the host-side brain that runs at segment boundaries:

- ``submit`` queues a request (validated against pool capacity once);
- ``try_admit`` moves queued requests into free batch slots while the
  page allocator can cover each request's whole lifetime
  (``prompt + max_new + 1`` tokens) — all-or-nothing, FIFO order (no
  overtaking: a small request never starves a big head-of-line one).
  With prefix sharing enabled, the admission first consults the
  :class:`~repro.serving.paged_cache.PrefixCache`: pages already holding
  an identical page-aligned prompt prefix are *mapped* (refcount bump)
  instead of allocated, only the uncovered suffix needs fresh pages, and
  the engine's ragged prefill computes only that suffix.  A matching
  partially-filled tail page is claimed copy-on-write: the source page is
  pinned with an extra reference (``cow_src``) until the engine has
  copied it into the request's own tail page at the boundary dispatch.
- ``complete`` retires a finished request, dropping one reference per
  page; pages whose last reference dies return to the free list — the
  very next ``try_admit`` can hand them out, which is the
  continuous-batching memory win over the contiguous cache's
  drain-the-whole-batch behavior.  Trie entries over still-shared pages
  stay valid (refcount > 0); entries over freed pages invalidate lazily
  through the allocator's generation counters.

Growth-on-demand admission (admit on prompt pages only, allocate decode
pages as generation proceeds, preempt on pool exhaustion) packs tighter
but needs in-flight preemption; it is a ROADMAP open item.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.serving.paged_cache import (PageAllocator, PagedCacheConfig,
                                       PrefixCache)


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""
    rid: Any
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival: float = 0.0               # offset from engine start (bench)

    # runtime state, owned by the scheduler/engine
    slot: int | None = None
    pages: list[int] | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    t_admitted: float | None = None
    t_done: float | None = None
    # prefix-sharing state: tokens [0, shared_tokens) are served by mapped
    # pages; the engine prefills only [shared_tokens, prompt_len).
    shared_tokens: int = 0
    shared_pages: int = 0              # full pages mapped from the trie
    cow_src: int | None = None         # tail page to copy-on-write from
    cow_dst: int | None = None         # the request's own tail page

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.t_done is not None


class ContinuousBatchingScheduler:
    def __init__(self, pcfg: PagedCacheConfig, *,
                 sharing: bool | None = None):
        self.pcfg = pcfg
        self.allocator = PageAllocator(pcfg.n_pages)
        self.sharing = (pcfg.enable_prefix_sharing if sharing is None
                        else bool(sharing))
        self.prefix_cache = PrefixCache(
            self.allocator, pcfg.page_size,
            chunk_pages=pcfg.prefix_chunk_pages) if self.sharing else None
        self.pending: deque[Request] = deque()
        self.running: dict[int, Request] = {}       # slot -> request
        self.free_slots = sorted(range(pcfg.max_slots))
        self.finished: list[Request] = []
        self.n_admitted = 0

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.running)

    def submit(self, req: Request) -> None:
        self.pcfg.validate_request(req.prompt_len, req.max_new_tokens)
        self.pending.append(req)

    def try_admit(self) -> list[Request]:
        """Admit queued requests while a slot and enough pages are free."""
        admitted = []
        while self.pending and self.free_slots:
            req = self.pending[0]
            need = self.pcfg.pages_for(req.prompt_len
                                       + req.max_new_tokens + 1)
            match = None
            if self.prefix_cache is not None:
                match = self.prefix_cache.lookup(req.prompt)
            n_shared = len(match.pages) if match else 0
            pages = self.allocator.alloc(need - n_shared)
            if pages is None:
                break                     # FIFO: wait for pages to free up
            self.pending.popleft()
            if match and match.pages:
                self.allocator.share(list(match.pages))
            req.pages = list(match.pages) + pages if match else pages
            req.shared_pages = n_shared
            req.shared_tokens = match.n_tokens if match else 0
            if match and match.tail_src is not None:
                # pin the CoW source until the engine has copied it —
                # its owner could complete before the boundary dispatch.
                # The fork target is the page holding the LAST matched
                # token (n_tokens // page_size would index one page past
                # it when the matched tail fills its page exactly, which
                # multi-page chunk granules make reachable).
                self.allocator.share([match.tail_src])
                req.cow_src = match.tail_src
                req.cow_dst = req.pages[(match.n_tokens - 1)
                                        // self.pcfg.page_size]
            if self.prefix_cache is not None:
                self.prefix_cache.record(match)
                self.prefix_cache.insert(req.prompt, req.prompt_len,
                                         req.pages)
            req.slot = self.free_slots.pop(0)
            self.running[req.slot] = req
            self.n_admitted += 1
            admitted.append(req)
        return admitted

    def finish_boundary(self, admitted: list[Request]) -> None:
        """Called by the engine after the admission-boundary dispatch:
        CoW copies have landed (drop the source pins) and the admitted
        requests' prompt K/V is on device (trie entries become ready)."""
        for req in admitted:
            if req.cow_src is not None:
                self.allocator.release([req.cow_src])
                req.cow_src = None
        if self.prefix_cache is not None:
            self.prefix_cache.mark_ready()

    def complete(self, slot: int) -> Request:
        """Retire the request in ``slot``; pages whose last reference
        dies are free for the next admission immediately."""
        req = self.running.pop(slot)
        if req.cow_src is not None:       # engine never ran the boundary
            self.allocator.release([req.cow_src])
            req.cow_src = None
        self.allocator.release(req.pages)
        req.pages = None
        req.slot = None
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.finished.append(req)
        return req

    def stats(self) -> dict[str, int | float]:
        """Prefix-sharing counters for benches/telemetry."""
        pc = self.prefix_cache
        return {
            "pages_allocated_total": self.allocator.pages_allocated_total,
            "pages_shared_total": self.allocator.pages_shared_total,
            "prefix_lookups": pc.lookups if pc else 0,
            "prefix_hits": pc.hits if pc else 0,
            "prefix_tokens_matched": pc.tokens_matched if pc else 0,
        }
