"""Paged KV-cache: fixed-size page pool + free-list allocator + block tables.

The contiguous decode cache (models/layers.py::init_attention_cache) ties
one request to one ``(cache_len, KV, hd)`` strip for its whole lifetime —
memory is reserved for the *longest possible* generation of the *whole
batch*, and a finished request's strip is dead weight until the entire
batch drains.  Paging decouples the two: KV state lives in a shared pool
of ``page_size``-token pages, each request owns an ordered list of pages
(its *block table* row), and completion returns pages to a free list the
next admission reuses immediately.  That is the memory architecture the
continuous-batching scheduler (serving/scheduler.py) allocates against.

Layout per layer: ``k_pages``/``v_pages``: (n_pages, page_size, KV, hd),
stacked over layers by :func:`init_paged_cache` exactly like the
contiguous cache so lm_apply's layer scan carries it unchanged.  Physical
page 0 is reserved as the *scratch page* (:data:`TRASH_PAGE`): empty or
drained batch slots keep running inside a jitted decode segment, and
their (masked, discarded) writes land there instead of corrupting pages
the allocator may already have handed to another request.

The page size is an optimization knob like any tile size: small pages
waste less pool memory on partial tails (internal fragmentation ~
``page_size/2`` tokens per request) but mean more grid steps and more
page-granular DMAs for the paged decode kernel; big pages invert the
trade.  It is tuned per shape through kernels/autotune.py
(``flash_decode_paged``) and read back via :func:`preferred_page_size`
at pool-construction time — the layout is fixed once allocated.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig

TRASH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry + scheduler cadence for one serving engine."""
    page_size: int = 16
    n_pages: int = 64        # physical pages per layer, incl. the scratch page
    max_slots: int = 8       # in-flight batch width R
    max_blocks: int = 8      # block-table width M (logical pages per request)
    segment_len: int = 8     # decode steps between scheduler syncs

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache slots."""
        return -(-int(n_tokens) // self.page_size)

    @property
    def capacity_tokens(self) -> int:
        """Max cache tokens a single request can hold (block-table width)."""
        return self.max_blocks * self.page_size

    @property
    def allocatable_pages(self) -> int:
        return self.n_pages - 1  # page 0 is the scratch page

    def validate_request(self, prompt_len: int, max_new_tokens: int) -> int:
        """Pages a request needs for its whole lifetime; raises if it can
        never fit.  +1 slot: the last decode step still writes its token's
        K/V before the engine retires the request."""
        need_tokens = prompt_len + max_new_tokens + 1
        if need_tokens > self.capacity_tokens:
            raise ValueError(
                f"request needs {need_tokens} cache slots > capacity "
                f"{self.capacity_tokens} (max_blocks={self.max_blocks} x "
                f"page_size={self.page_size})")
        need = self.pages_for(need_tokens)
        if need > self.allocatable_pages:
            raise ValueError(f"request needs {need} pages > pool "
                             f"{self.allocatable_pages}")
        return need


class PageAllocator:
    """Host-side free-list allocator over the physical page pool.

    Page ids are handed out lowest-first and returned pages are reused
    before fresh ones — the pool working set stays compact, and tests can
    assert literal page-id reuse after a request completes.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page "
                             "beyond the reserved scratch page")
        self._free = list(range(n_pages - 1, 0, -1))  # pop() -> ascending
        self._held: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """``n`` pages, or None (allocation is all-or-nothing)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._held.update(pages)
        return pages

    def release(self, pages: list[int]) -> None:
        for p in pages:
            if p not in self._held:
                raise ValueError(f"double free or foreign page {p}")
            self._held.discard(p)
        # freed pages go to the top of the stack: first to be reused
        self._free.extend(sorted(pages, reverse=True))


def supports_paging(cfg: ArchConfig) -> bool:
    """Paged decode covers the dense-attention families with linear
    caches.  Sliding-window ring buffers recycle slots *within* a request
    (a different page-reuse problem — ROADMAP open item), MLA caches
    compressed latents, and SSM/hybrid families carry recurrent state.

    getattr-defensive like the rest of tasks/tune.py::derive_problems —
    TUNE probes duck-typed handle configs that may carry only the
    attention fields.
    """
    return (getattr(cfg, "family", None) in ("dense", "moe", "vlm")
            and not getattr(cfg, "use_mla", False)
            and not getattr(cfg, "sliding_window", 0)
            and not getattr(cfg, "enc_dec", False))


def init_paged_cache(cfg: ArchConfig, pcfg: PagedCacheConfig,
                     dtype=jnp.bfloat16):
    """Whole-model paged cache pytree (+ logical axes).

    ``blocks`` stacks the per-layer page pools on a leading layer axis —
    the same shape contract as init_lm_cache, so lm_apply's scan carries
    it directly; ``block_tables``/``seq_lens`` are batch state shared by
    every layer and injected per layer inside the scan body.
    """
    if not supports_paging(cfg):
        raise ValueError(f"{cfg.name}: family={cfg.family} "
                         f"window={cfg.sliding_window} mla={cfg.use_mla} "
                         f"does not support the paged decode path")
    shape = (cfg.n_layers, pcfg.n_pages, pcfg.page_size,
             cfg.n_kv_heads, cfg.hd)
    cache = {
        "blocks": {"k_pages": jnp.zeros(shape, dtype),
                   "v_pages": jnp.zeros(shape, dtype)},
        "block_tables": jnp.full((pcfg.max_slots, pcfg.max_blocks),
                                 TRASH_PAGE, jnp.int32),
        "seq_lens": jnp.zeros((pcfg.max_slots,), jnp.int32),
    }
    axes = {
        "blocks": {"k_pages": ("layers", "kv_pages", None, "kv_heads",
                               "head_dim"),
                   "v_pages": ("layers", "kv_pages", None, "kv_heads",
                               "head_dim")},
        "block_tables": (None, None),
        "seq_lens": (None,),
    }
    return cache, axes


def preferred_page_size(cfg: ArchConfig, pcfg_slots: int,
                        max_len: int) -> int:
    """Tuned page size for this arch's decode shape, from the autotuner's
    persisted cache (pure read — tuning happens in the TUNE task or the
    ``tuned_*`` wrappers, never at pool-construction time).  Falls back
    to the kernel default on a miss."""
    from repro.kernels import autotune
    prob = autotune.flash_decode_paged_problem(
        pcfg_slots, cfg.n_heads, cfg.n_kv_heads, cfg.hd, max_len,
        str(cfg.adt))
    tile = autotune.cached_config("flash_decode_paged", prob,
                                  relax=("slots", "max_len"))
    return int(tile["page_size"])
