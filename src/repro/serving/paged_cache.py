"""Paged KV-cache: fixed-size page pool + free-list allocator + block tables.

The contiguous decode cache (models/layers.py::init_attention_cache) ties
one request to one ``(cache_len, KV, hd)`` strip for its whole lifetime —
memory is reserved for the *longest possible* generation of the *whole
batch*, and a finished request's strip is dead weight until the entire
batch drains.  Paging decouples the two: KV state lives in a shared pool
of ``page_size``-token pages, each request owns an ordered list of pages
(its *block table* row), and completion returns pages to a free list the
next admission reuses immediately.  That is the memory architecture the
continuous-batching scheduler (serving/scheduler.py) allocates against.

Layout per layer: ``k_pages``/``v_pages``: (n_pages, page_size, KV, hd),
stacked over layers by :func:`init_paged_cache` exactly like the
contiguous cache so lm_apply's layer scan carries it unchanged.  Physical
page 0 is reserved as the *scratch page* (:data:`TRASH_PAGE`): empty or
drained batch slots keep running inside a jitted decode segment, and
their (masked, discarded) writes land there instead of corrupting pages
the allocator may already have handed to another request.

The page size is an optimization knob like any tile size: small pages
waste less pool memory on partial tails (internal fragmentation ~
``page_size/2`` tokens per request) but mean more grid steps and more
page-granular DMAs for the paged decode kernel; big pages invert the
trade.  It is tuned per shape through kernels/autotune.py
(``flash_decode_paged``) and read back via :func:`preferred_page_size`
at pool-construction time — the layout is fixed once allocated.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

TRASH_PAGE = 0


class AllocatorError(ValueError):
    """Allocator misuse: double free, sharing a free page, negative
    alloc.  A real exception rather than an ``assert`` so the checks
    survive ``python -O``, and a dedicated type so the recovery layer
    (serving/recovery.py) can quarantine the offending request instead
    of crashing the engine.  Subclasses ValueError for back-compat with
    callers that caught the old untyped raises."""


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry + scheduler cadence for one serving engine."""
    page_size: int = 16
    n_pages: int = 64        # physical pages per layer, incl. the scratch page
    max_slots: int = 8       # in-flight batch width R
    max_blocks: int = 8      # block-table width M (logical pages per request)
    segment_len: int = 8     # decode steps between scheduler syncs
    # Prefix sharing: admissions map already-resident pages holding an
    # identical page-aligned prompt prefix instead of recomputing and
    # re-storing them (refcounted; decode writes into a shared tail page
    # fork a private copy first).  The match granule is
    # ``prefix_chunk_pages * page_size`` tokens — page_size flows from the
    # autotuner (preferred_page_size), so the granularity is a tuned
    # quantity, not a constant.
    enable_prefix_sharing: bool = True
    prefix_chunk_pages: int = 1   # trie-edge granularity, in pages
    # Batched admission prefill pads each admission's suffix to a multiple
    # of this bucket so one boundary's admissions share a single ragged
    # dispatch with a bounded number of compiled shapes.
    prefill_bucket: int = 8
    # Growth-on-demand granule, in pages: at each segment boundary the
    # resource manager (serving/resources.py) tops a running request up to
    # the next segment's coverage in multiples of this, trading allocator
    # churn against packing slack.  0 = auto: the pages one decode segment
    # consumes — which makes the granule a tuned quantity, since both
    # page_size (flash_decode_paged) and segment_len (paged_segment) come
    # from the autotuner.
    growth_pages: int = 0
    # Prefix-cache retention: an LRU budget of pages the PrefixCache
    # itself holds references on, so a hot prefix (a system prompt)
    # survives the idle gap after its last request completes.  Pinned
    # pages are evicted instantly under allocator pressure (the resource
    # manager's pressure callback) before any request is preempted.
    retain_pages: int = 0

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache slots."""
        return -(-int(n_tokens) // self.page_size)

    @property
    def growth_granule(self) -> int:
        """Pages added per growth step (auto: one segment's worth)."""
        return self.growth_pages or max(1, self.pages_for(self.segment_len))

    def lifetime_tokens(self, prompt_len: int, max_new_tokens: int) -> int:
        """Cache slots a request occupies when fully generated (+1: the
        final decode step still writes its token's K/V)."""
        return prompt_len + max_new_tokens + 1

    def coverage_tokens(self, seq_len: int, prompt_len: int,
                        max_new_tokens: int) -> int:
        """Cache slots that must be page-backed before the next decode
        segment, given ``seq_len`` resident tokens: one segment of
        writes plus the parked write slot an inactive row keeps using,
        capped at the whole lifetime.  This single formula IS the
        stall-safety invariant — admission, growth, and restore all size
        against it, so a slot denied growth can sit a segment out with
        its frozen write slot still inside pages it owns."""
        return min(seq_len + self.segment_len + 1,
                   self.lifetime_tokens(prompt_len, max_new_tokens))

    def admission_tokens(self, prompt_len: int, max_new_tokens: int) -> int:
        """Coverage a fresh admission needs: the prompt is the resident
        position.  Everything past this is allocated on demand at later
        segment boundaries."""
        return self.coverage_tokens(prompt_len, prompt_len,
                                    max_new_tokens)

    @property
    def prefix_match_tokens(self) -> int:
        """Tokens per prefix-trie edge (the sharing granule)."""
        return self.prefix_chunk_pages * self.page_size

    def to_dict(self) -> dict[str, Any]:
        """JSON-safe dict (checkpoint ``extra`` payloads)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "PagedCacheConfig":
        """Inverse of :meth:`to_dict`.  Unknown keys are dropped and
        missing ones take their defaults, so configs persisted before a
        knob existed (or after one is retired) stay loadable."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    @property
    def capacity_tokens(self) -> int:
        """Max cache tokens a single request can hold (block-table width)."""
        return self.max_blocks * self.page_size

    @property
    def allocatable_pages(self) -> int:
        return self.n_pages - 1  # page 0 is the scratch page

    def validate_request(self, prompt_len: int, max_new_tokens: int) -> int:
        """Pages a request needs for its whole lifetime; raises if it can
        never fit.  +1 slot: the last decode step still writes its token's
        K/V before the engine retires the request."""
        need_tokens = prompt_len + max_new_tokens + 1
        if need_tokens > self.capacity_tokens:
            raise ValueError(
                f"request needs {need_tokens} cache slots > capacity "
                f"{self.capacity_tokens} (max_blocks={self.max_blocks} x "
                f"page_size={self.page_size})")
        need = self.pages_for(need_tokens)
        if need > self.allocatable_pages:
            raise ValueError(f"request needs {need} pages > pool "
                             f"{self.allocatable_pages}")
        return need


class PageAllocator:
    """Host-side refcounted free-list allocator over the physical page pool.

    Page ids are handed out lowest-first and returned pages are reused
    before fresh ones — the pool working set stays compact, and tests can
    assert literal page-id reuse after a request completes.

    Prefix sharing maps one physical page into several requests' block
    tables; each mapping holds a reference (:meth:`share`), and a page
    only returns to the free list when its last reference is released.
    Every alloc bumps the page's *generation* — the prefix trie records
    (page, generation) so an entry for a page that was freed and
    re-issued to unrelated content can never validate.
    """

    def __init__(self, n_pages: int, faults=None):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page "
                             "beyond the reserved scratch page")
        # Optional FaultPlan (serving/faults.py): the "alloc" site makes
        # alloc() bounce as if the pool were dry — indistinguishable from
        # real pressure, so callers exercise their real fallback paths.
        self._faults = faults
        self._free = list(range(n_pages - 1, 0, -1))  # pop() -> ascending
        self._refs: dict[int, int] = {}               # page -> refcount
        self._gen = [0] * n_pages                     # bumped per alloc
        self.pages_allocated_total = 0                # fresh allocs (stats)
        self.pages_shared_total = 0                   # share() refs (stats)
        # pressure telemetry: the tightest the pool ever got, and how many
        # alloc() calls bounced — what the resource manager's preemption
        # policy and the bench rows read back
        self.free_low_water = n_pages - 1
        self.alloc_failures = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_held(self) -> int:
        """Distinct physical pages currently referenced."""
        return len(self._refs)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def generation(self, page: int) -> int:
        return self._gen[page]

    def is_shared(self, page: int) -> bool:
        return self._refs.get(page, 0) > 1

    def alloc(self, n: int) -> list[int] | None:
        """``n`` fresh pages at refcount 1, or None (all-or-nothing)."""
        if n < 0:
            raise AllocatorError(f"alloc({n})")
        if n > 0 and self._faults is not None \
                and self._faults.should_fire("alloc"):
            self.alloc_failures += 1
            return None
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
            self._gen[p] += 1
        self.pages_allocated_total += n
        self.free_low_water = min(self.free_low_water, len(self._free))
        return pages

    def share(self, pages: list[int]) -> None:
        """Add one reference per page (mapping live pages into another
        request's block table).  Sharing a free page is a bug."""
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise AllocatorError(f"cannot share free/foreign page {p}")
        for p in pages:
            self._refs[p] += 1
        self.pages_shared_total += len(pages)

    def release(self, pages: list[int]) -> list[int]:
        """Drop one reference per page; pages hitting refcount 0 return
        to the free list (returned for tests/telemetry)."""
        freed: list[int] = []
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise AllocatorError(f"double free or foreign page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                freed.append(p)
        # freed pages go to the top of the stack: first to be reused
        self._free.extend(sorted(freed, reverse=True))
        return freed


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of a prefix-cache lookup against one prompt."""
    pages: tuple[int, ...] = ()     # full-chunk physical pages, in order
    n_tokens: int = 0               # tokens covered (full chunks + tail)
    tail_src: int | None = None     # page to copy-on-write the tail from
    tail_tokens: int = 0            # tokens matched inside the tail page


class _TrieNode:
    __slots__ = ("children", "tails")

    def __init__(self):
        # token-chunk -> (pages, gens, ready, child)
        self.children: dict[tuple, list] = {}
        # partial-page tail tokens -> [page, gen, ready]
        self.tails: dict[tuple, list] = {}


class PrefixCache:
    """Prefix trie over token-id page chunks -> resident physical pages.

    Each edge covers ``chunk_pages`` full pages of prompt tokens starting
    at a fixed absolute position (trie depth x chunk tokens), so a match
    guarantees the stored pages hold K/V for *these tokens at these
    positions* — sharing is a pure block-table aliasing, no recompute.

    Entries carry the allocator generation captured at insert; lookups
    re-validate ``refcount > 0 and generation unchanged`` and prune stale
    entries lazily, so completion never has to notify the trie.

    Tail entries index a request's final *partially filled* prompt page.
    That page is mutable (its owner decodes into it), so a tail match is
    satisfied by copy-on-write: the matching prompt slots are copied into
    a page the new request owns before its first write.  Tail entries
    only become matchable once :meth:`mark_ready` confirms their K/V has
    materialized on device — a same-boundary admission must not CoW-copy
    a page whose prefill is still in flight.  Full-chunk entries are
    matchable immediately: same-boundary sharers read them *after* the
    batched prefill's in-graph scatter, inside the same dispatch.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 chunk_pages: int = 1, retain_pages: int = 0):
        self.allocator = allocator
        self.page_size = int(page_size)
        self.chunk_pages = int(chunk_pages)
        self.chunk_tokens = self.page_size * self.chunk_pages
        self.root = _TrieNode()
        self._pending: list[list] = []   # entries awaiting mark_ready
        self.lookups = 0
        self.hits = 0                    # lookups matching >= 1 token
        self.tokens_matched = 0
        # Retention pins: an LRU of <= retain_pages full-chunk pages the
        # cache itself holds one reference on, so a hot prefix outlives
        # its last request.  A pinned page can never be freed, so its
        # generation never moves and its trie entries stay valid — the
        # pin IS the retention.  Only immutable full-chunk pages are
        # pinned (a tail page's owner decodes into it).
        self.retain_pages = int(retain_pages)
        self._pins: OrderedDict[int, None] = OrderedDict()
        self.pin_evictions = 0

    def _entry_valid(self, pages, gens) -> bool:
        alloc = self.allocator
        return all(alloc.refcount(p) > 0 and alloc.generation(p) == g
                   for p, g in zip(pages, gens))

    def lookup(self, tokens: np.ndarray) -> PrefixMatch:
        """Longest resident prefix of ``tokens``, full chunks first, then
        one partial-tail page.  At least one trailing token is always
        left unmatched — the admission prefill must still produce the
        request's first-token logits.

        Pure read apart from lazy pruning: the hit/token counters only
        move when :meth:`record` confirms the match was consumed by an
        admission (a blocked head-of-line request is looked up again at
        every boundary and must not inflate the stats)."""
        toks = [int(t) for t in tokens]
        cap = len(toks) - 1              # always >= 1 suffix token
        node = self.root
        pages: list[int] = []
        pos = 0
        ct = self.chunk_tokens
        while pos + ct <= cap:
            key = tuple(toks[pos:pos + ct])
            entry = node.children.get(key)
            if entry is None:
                break
            e_pages, e_gens, _ready, child = entry
            if not self._entry_valid(e_pages, e_gens):
                del node.children[key]   # lazy prune of stale entries
                break
            pages.extend(e_pages)
            pos += ct
            node = child
        tail_src, tail_tokens = None, 0
        budget = cap - pos
        if 0 < budget:
            for key, entry in list(node.tails.items()):
                page, gen, ready = entry
                if not self._entry_valid((page,), (gen,)):
                    del node.tails[key]
                    continue
                if not ready:
                    continue
                m = 0
                for a, b in zip(key, toks[pos:pos + budget]):
                    if a != b:
                        break
                    m += 1
                if m > tail_tokens:
                    tail_src, tail_tokens = page, m
        return PrefixMatch(pages=tuple(pages), n_tokens=pos + tail_tokens,
                           tail_src=tail_src, tail_tokens=tail_tokens)

    def record(self, match: PrefixMatch) -> None:
        """Count a lookup whose result an admission actually consumed."""
        self.lookups += 1
        if match.n_tokens:
            self.hits += 1
            self.tokens_matched += match.n_tokens
            self._touch_pins(match.pages)    # a consumed hit is "hot"

    # ------------------------------------------------------ retention pins
    def _touch_pins(self, pages) -> None:
        """LRU-touch ``pages``; pin live unpinned ones under the budget,
        evicting the coldest pins to make room.  ``pages`` arrive in
        prefix order and are touched in *reverse*: trie matching is
        sequential from the root, so a deep page is worthless without the
        shallow ones before it — touching shallow pages last keeps them
        hottest, and eviction truncates the retained prefix from its
        tail instead of beheading it."""
        if not self.retain_pages:
            return
        for p in reversed(list(pages)):
            if p in self._pins:
                self._pins.move_to_end(p)
            elif self.allocator.refcount(p) > 0:
                while len(self._pins) >= self.retain_pages:
                    self._evict_pin()
                self.allocator.share([p])
                self._pins[p] = None

    def _evict_pin(self) -> int:
        """Drop the LRU pin; returns how many pages actually freed (0 when
        other requests still reference the page)."""
        page, _ = self._pins.popitem(last=False)
        self.pin_evictions += 1
        return len(self.allocator.release([page]))

    def release_pins(self, n_pages: int) -> int:
        """Allocator-pressure callback: evict LRU pins until ``n_pages``
        pages returned to the free list (or no pins remain).  Retention is
        strictly weaker than any request's demand — the resource manager
        calls this before considering preemption."""
        freed = 0
        while self._pins and freed < n_pages:
            freed += self._evict_pin()
        return freed

    @property
    def pinned_pages(self) -> int:
        return len(self._pins)

    def insert(self, tokens: np.ndarray, prompt_len: int,
               pages: list[int]) -> None:
        """Register an admitted request's prompt pages.

        Full chunks whose last token lies within the prompt are immutable
        (decode writes start at ``prompt_len``, which lives in a later
        page) and are indexed directly; a trailing partial page becomes a
        tail entry.  Both are queued not-ready until :meth:`mark_ready`.
        """
        toks = [int(t) for t in tokens[:prompt_len]]
        alloc = self.allocator
        node = self.root
        ct = self.chunk_tokens
        pos = 0
        while pos + ct <= prompt_len:
            key = tuple(toks[pos:pos + ct])
            blk = pos // self.page_size
            e_pages = tuple(pages[blk:blk + self.chunk_pages])
            entry = node.children.get(key)
            if entry is not None and self._entry_valid(entry[0], entry[1]):
                node = entry[3]          # already indexed (shared hit)
            else:
                gens = tuple(alloc.generation(p) for p in e_pages)
                child = _TrieNode()
                new = [e_pages, gens, False, child]
                node.children[key] = new
                self._pending.append(new)
                node = child
            pos += ct
        # tail entries index exactly one page past the full chunks; with
        # a multi-page chunk granule, a sub-chunk run spanning several
        # pages is the (accepted) coarseness cost and is not indexed
        if pos < prompt_len and prompt_len - pos <= self.page_size:
            key = tuple(toks[pos:])
            entry = node.tails.get(key)
            if entry is None or not self._entry_valid((entry[0],),
                                                      (entry[1],)):
                page = pages[pos // self.page_size]
                new = [page, alloc.generation(page), False]
                node.tails[key] = new
                self._pending.append(new)

    def mark_ready(self) -> None:
        """Confirm queued entries: their K/V has been dispatched to the
        device (the admission-boundary prefill ran)."""
        pinnable: list[int] = []
        for entry in self._pending:
            entry[2] = True              # ready slot of both entry kinds
            if len(entry) == 4:          # full-chunk entry: pinnable
                pinnable.extend(entry[0])
        # one prefix-ordered touch across the whole boundary, so the
        # reverse-touch policy sees the chunks in trie order
        self._touch_pins(pinnable)
        self._pending.clear()


def supports_paging(cfg: ArchConfig) -> bool:
    """Paged decode covers the dense-attention families with linear
    caches.  Sliding-window ring buffers recycle slots *within* a request
    (a different page-reuse problem — ROADMAP open item), MLA caches
    compressed latents, and SSM/hybrid families carry recurrent state.

    getattr-defensive like the rest of tasks/tune.py::derive_problems —
    TUNE probes duck-typed handle configs that may carry only the
    attention fields.
    """
    return (getattr(cfg, "family", None) in ("dense", "moe", "vlm")
            and not getattr(cfg, "use_mla", False)
            and not getattr(cfg, "sliding_window", 0)
            and not getattr(cfg, "enc_dec", False))


def init_paged_cache(cfg: ArchConfig, pcfg: PagedCacheConfig,
                     dtype=jnp.bfloat16):
    """Whole-model paged cache pytree (+ logical axes).

    ``blocks`` stacks the per-layer page pools on a leading layer axis —
    the same shape contract as init_lm_cache, so lm_apply's scan carries
    it directly; ``block_tables``/``seq_lens`` are batch state shared by
    every layer and injected per layer inside the scan body.
    """
    if not supports_paging(cfg):
        raise ValueError(f"{cfg.name}: family={cfg.family} "
                         f"window={cfg.sliding_window} mla={cfg.use_mla} "
                         f"does not support the paged decode path")
    shape = (cfg.n_layers, pcfg.n_pages, pcfg.page_size,
             cfg.n_kv_heads, cfg.hd)
    cache = {
        "blocks": {"k_pages": jnp.zeros(shape, dtype),
                   "v_pages": jnp.zeros(shape, dtype)},
        "block_tables": jnp.full((pcfg.max_slots, pcfg.max_blocks),
                                 TRASH_PAGE, jnp.int32),
        "seq_lens": jnp.zeros((pcfg.max_slots,), jnp.int32),
    }
    axes = {
        "blocks": {"k_pages": ("layers", "kv_pages", None, "kv_heads",
                               "head_dim"),
                   "v_pages": ("layers", "kv_pages", None, "kv_heads",
                               "head_dim")},
        "block_tables": (None, None),
        "seq_lens": (None,),
    }
    return cache, axes


def preferred_page_size(cfg: ArchConfig, pcfg_slots: int,
                        max_len: int) -> int:
    """Tuned page size for this arch's decode shape, from the autotuner's
    persisted cache (pure read — tuning happens in the TUNE task or the
    ``tuned_*`` wrappers, never at pool-construction time).  Falls back
    to the kernel default on a miss.

    Thin wrapper over the consolidated readback
    (:func:`repro.kernels.autotune.tile_readback` — the relax keys live
    in ``autotune.TILE_RELAX``, not here); the provenance-tracked form
    is ``ServingPlan.resolve`` (serving/plan.py)."""
    from repro.kernels import autotune
    prob = autotune.flash_decode_paged_problem(
        pcfg_slots, cfg.n_heads, cfg.n_kv_heads, cfg.hd, max_len,
        str(cfg.adt))
    tile, _ = autotune.tile_readback("flash_decode_paged", prob)
    return int(tile["page_size"])


def preferred_segment_len(cfg: ArchConfig, pcfg_slots: int,
                          max_len: int) -> int:
    """Tuned decode-segment length (scheduler cadence) for this arch's
    serving shape — same pure-read contract as
    :func:`preferred_page_size`.  The problem is keyed against the tuned
    page size, so TUNE picks the cadence for the pool layout it itself
    selected; with it comes the resource manager's default growth
    granule (``PagedCacheConfig.growth_granule`` = pages per segment),
    making both the segment length and the growth granule tuned
    quantities rather than constants."""
    from repro.kernels import autotune
    ps = preferred_page_size(cfg, pcfg_slots, max_len)
    prob = autotune.paged_segment_problem(
        pcfg_slots, cfg.n_heads, cfg.n_kv_heads, cfg.hd, max_len, ps,
        str(cfg.adt))
    tile, _ = autotune.tile_readback("paged_segment", prob)
    return int(tile["segment_len"])
