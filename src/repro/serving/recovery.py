"""Request-level recovery for the serving engine: checkpoints,
quarantine, retries, and graceful degradation.

Before this layer, every anomaly in the boundary loop was a bare
``RuntimeError`` that killed the whole engine — and with it every
co-resident tenant's in-flight requests.  The recovery model instead
treats faults the way the resource manager treats page pressure: as a
per-request event with an automated policy response.

**Boundary checkpoints.**  At every segment boundary each running
request's committed state is exactly ``(tokens so far)`` — the device
pages hold K/V for positions ``[0, prompt + len(tokens) - 1)`` and
everything a later segment writes lands strictly *beyond* that
watermark (decode appends; masked positions are dead until their write
lands).  So the per-boundary checkpoint is one integer
(``Request.ckpt_tokens``), and rollback is: truncate the token list to
the checkpoint, snapshot the pages that back it through the *existing*
preemption machinery (``ResourceManager.preempt`` → ``SwapState`` host
image), and requeue.  The restore path then resumes bit-identically,
exactly as it does for an ordinary preemption.

**Quarantine lifecycle.**  A faulted request is quarantined: its slot
is vacated (healthy slots keep generating), its state rolls back to the
last checkpoint, and it waits out an exponential *segment* backoff
(``backoff_segments * backoff_factor**(n_retries-1)`` boundaries) before
re-entering its tenant's queue — through the preempted lane when a
verified host image exists (one-dispatch restore), through the pending
lane as a full restart when it does not (greedy decode is deterministic,
so a restart regenerates the same tokens).  Retries are bounded;
exhaustion dead-letters the request with a typed :class:`RequestFailed`
terminal record and per-tenant accounting in
``ResourceManager.stats()``.

**Swap integrity.**  Swap images carry a CRC recorded at ``device_get``
time; a corrupted or lost image is detected *before* its restore is
planned (``verify_swaps``) and converts the request to a restart instead
of scattering garbage K/V back into the pool.

**Invariant checker (opt-in).**  ``RecoveryPolicy.check_invariants``
audits the boundary state — block-table coverage ⊆ owned pages,
refcount and quota ledgers consistent — and quarantines the offending
request (full restart: its state is suspect) instead of crashing.  It
walks every running request's page list each boundary, so it costs
O(running x pages) host work per boundary: cheap next to a dispatch,
but nonzero — hence opt-in, for chaos runs and debugging.

**Watchdog.**  The engine's no-progress guard raises
:class:`EngineStalledError` carrying a structured diagnostic snapshot
(queue depths, free pages, per-slot state, quarantine/dead-letter
counts) — the one remaining way out of ``run()``, reserved for genuine
policy deadlocks and unbounded fault patterns.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import TYPE_CHECKING, Any, Iterable

from repro.serving.faults import image_checksum

if TYPE_CHECKING:                       # import cycle: engine imports us
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         Request)


class EngineStalledError(RuntimeError):
    """The engine made no progress for ``watchdog_boundaries``
    consecutive boundaries.  Carries the structured diagnostic the old
    bare RuntimeError only alluded to."""

    def __init__(self, message: str, snapshot: dict):
        super().__init__(message)
        self.snapshot = snapshot


@dataclasses.dataclass(frozen=True)
class RequestFailed:
    """Typed terminal state of a dead-lettered request (attached as
    ``Request.failure``; the request is *not* in ``scheduler.finished``).

    ``site`` names the last fault site that drove the request under
    (a :data:`~repro.serving.faults.SITES` name where the origin is
    known, a recovery-layer tag like ``"shed"``/``"invariant"`` where it
    is not) and ``ckpt_tokens`` is the boundary-checkpoint watermark the
    request had committed when it died — together with ``tenant`` and
    ``retries`` this is the structured record ``RecoveryManager.stats()``
    exports per dead letter.
    """
    rid: Any
    tenant: str
    reason: str
    boundary: int                       # boundary index at dead-letter
    retries: int
    site: str = "unknown"               # last fault site (or policy tag)
    ckpt_tokens: int = 0                # committed tokens at death

    def record(self) -> dict:
        """JSON-safe dict for bench rows / telemetry."""
        return {"rid": self.rid, "tenant": self.tenant, "site": self.site,
                "reason": self.reason, "boundary": self.boundary,
                "retries": self.retries, "ckpt_tokens": self.ckpt_tokens}


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the recovery layer; defaults favor transparent retries.

    ``shed_after_boundaries`` arms load shedding: a queued request that
    stays inadmissible that many consecutive boundaries (sustained
    allocator/quota pressure) is dead-lettered instead of queueing
    forever.  None (default) never sheds.
    """
    max_retries: int = 3
    backoff_segments: int = 1           # quarantine wait after 1st fault
    backoff_factor: float = 2.0         # exponential per further retry
    max_backoff_segments: int = 32
    check_invariants: bool = False
    shed_after_boundaries: int | None = None
    watchdog_boundaries: int = 256

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_segments < 0 or self.max_backoff_segments < 0:
            raise ValueError("backoff bounds must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1.0")
        if self.shed_after_boundaries is not None \
                and self.shed_after_boundaries < 1:
            raise ValueError("shed_after_boundaries must be >= 1 or None")


class RecoveryManager:
    """Per-run fault bookkeeping: the quarantine pen, retry/backoff
    policy, swap-image verification, dead-letter records, and the
    invariant checker.  All device data movement stays in the engine;
    this object only decides and accounts (the ResourceManager split,
    applied to failure handling)."""

    def __init__(self, policy: RecoveryPolicy,
                 sched: "ContinuousBatchingScheduler"):
        self.policy = policy
        self.sched = sched
        self.rm = sched.rm
        # write-ahead journal (serving/journal.py), set by EngineRun when
        # durability is on: dead letters round-trip through it so a
        # restart re-emits the same typed terminal records
        self.journal = None
        # (request, boundary at which its backoff expires)
        self._quarantine: list[tuple["Request", int]] = []
        self.dead: list["Request"] = []
        self._queued_since: dict[Any, int] = {}   # rid -> boundary
        # stats()/diagnostic counters live in the scheduler's metrics
        # registry; the historical attributes read back through it, and
        # the tracer (None unless telemetry is on) gets the QUARANTINE/
        # RETRY/DEAD_LETTER lifecycle events
        self.obs = sched.obs
        self.tracer = self.obs.tracer
        self._rep = self.obs.replica
        rep = ("replica",)
        self._c_quar = self.obs.counter(
            "serving_quarantines_total",
            "requests quarantined, by fault site", ("replica", "site"))
        self._c_restarts = self.obs.counter(
            "serving_restarts_total",
            "quarantines that lost their swap image", rep)
        self._c_swapf = self.obs.counter(
            "serving_swap_faults_total",
            "corrupt/lost swap images detected pre-restore", rep)
        self._c_shed = self.obs.counter(
            "serving_shed_total",
            "queued requests shed under sustained pressure", rep)
        self._c_dispatch_faults = self.obs.counter(
            "serving_segment_dispatch_faults_total",
            "decode segment dispatches that raised", rep)
        self._c_retries = self.obs.counter(
            "serving_retries_total",
            "quarantined requests requeued after backoff", rep)
        self._c_inv = self.obs.counter(
            "serving_invariant_violations_total",
            "boundary-audit violations recorded", rep)
        self.invariant_violations: list[str] = []

    # --------------------------------------------- registry thin views
    @property
    def quarantines(self) -> int:
        return int(self._c_quar.total(replica=self._rep))

    @property
    def restarts(self) -> int:
        return int(self._c_restarts.total(replica=self._rep))

    @property
    def swap_faults_detected(self) -> int:
        return int(self._c_swapf.total(replica=self._rep))

    @property
    def shed(self) -> int:
        return int(self._c_shed.total(replica=self._rep))

    @property
    def segment_dispatch_faults(self) -> int:
        return int(self._c_dispatch_faults.total(replica=self._rep))

    @property
    def has_quarantined(self) -> bool:
        return bool(self._quarantine)

    # -------------------------------------------------------- checkpoints
    def checkpoint(self, running: Iterable["Request"]) -> None:
        """Record the boundary watermark every rollback targets.  Called
        once per boundary, after admissions and before the segment
        dispatch — the committed tokens at this instant are exactly what
        the device pages back."""
        for req in running:
            req.ckpt_tokens = len(req.tokens)

    # --------------------------------------------------------- quarantine
    def backoff(self, req: "Request") -> int:
        b = self.policy.backoff_segments * \
            self.policy.backoff_factor ** max(req.n_retries - 1, 0)
        return int(min(b, self.policy.max_backoff_segments))

    def hold(self, req: "Request", reason: str, boundary: int,
             now: float, site: str = "unknown") -> bool:
        """Quarantine ``req`` (already off-slot, pages released): bump
        its retry count and either park it for its backoff or dead-letter
        it when retries are exhausted.  Returns False on dead-letter."""
        req.n_retries += 1
        self._c_quar.inc(1.0, (self._rep, site))
        if req.swap is None:
            self._c_restarts.inc(1.0, (self._rep,))
        if self.tracer is not None:
            self.tracer.event(req.rid, "QUARANTINE", boundary, now,
                              site=site, reason=reason,
                              retries=req.n_retries,
                              has_image=req.swap is not None)
        if req.n_retries > self.policy.max_retries:
            self.dead_letter(req, f"retries exhausted after {reason}",
                             boundary, now, site=site)
            return False
        self._quarantine.append((req, boundary + self.backoff(req)))
        return True

    def release_due(self, boundary: int, now: float = 0.0) -> int:
        """Requeue quarantined requests whose backoff expired: verified
        host image → the tenant's preempted lane (one-dispatch restore);
        none → the pending lane (full restart)."""
        due = [(r, b) for r, b in self._quarantine if b <= boundary]
        if not due:
            return 0
        self._quarantine = [(r, b) for r, b in self._quarantine
                            if b > boundary]
        for req, _ in due:
            self.rm.requeue(req)
            self._c_retries.inc(1.0, (self._rep,))
            if self.tracer is not None:
                self.tracer.event(req.rid, "RETRY", boundary, now,
                                  retries=req.n_retries,
                                  has_image=req.swap is not None)
        return len(due)

    def drain_quarantined(self) -> "list[Request]":
        """Empty the quarantine pen (replica drain/failover): the cluster
        migrates these requests to another replica, backoff forgiven —
        the faulting engine is gone, so there is nothing to back off
        from."""
        out = [req for req, _ in self._quarantine]
        self._quarantine = []
        return out

    def reset_for_restart(self, req: "Request") -> None:
        """Strip a request back to as-submitted: no swap image, no
        tokens, no sharing state.  Greedy decode is deterministic, so a
        restart regenerates exactly the fault-free token stream."""
        req.swap = None
        req.tokens = []
        req.ckpt_tokens = 0
        req.shared_tokens = 0
        req.shared_pages = 0
        req.cow_src = None
        req.cow_dst = None
        req.restore_blocks = (0, 0)
        req.stalled = False
        req.protected = False
        req.slot = None

    # -------------------------------------------------------- dead letter
    def dead_letter(self, req: "Request", reason: str, boundary: int,
                    now: float, site: str = "unknown") -> None:
        req.swap = None
        req.failure = RequestFailed(rid=req.rid, tenant=req.tenant,
                                    reason=reason, boundary=boundary,
                                    retries=req.n_retries, site=site,
                                    ckpt_tokens=req.ckpt_tokens)
        req.t_done = now
        self.rm.note_dead_letter(req, site)
        self.dead.append(req)
        if self.tracer is not None:
            self.tracer.event(req.rid, "DEAD_LETTER", boundary, now,
                              site=site, reason=reason,
                              retries=req.n_retries)
        if self.journal is not None:
            self.journal.dead_letter(req.failure.record())

    # ------------------------------------------------------ swap integrity
    def verify_swaps(self, boundary: int, now: float) -> int:
        """Verify each queued restore's host image once (CRC recorded at
        swap-out).  A corrupted or lost image converts the request to a
        quarantined restart — scattering it back would poison the pool.
        Returns the number of conversions."""
        converted = 0
        for st in self.rm._tenants.values():
            keep: deque = deque()
            for req in st.preempted:
                sw = req.swap
                if sw is not None and not sw.verified:
                    sw.verified = True
                    lost = sw.host_k is None or sw.host_v is None
                    ok = not lost and (sw.checksum is None or sw.checksum
                                       == image_checksum(sw.host_k,
                                                         sw.host_v))
                    if not ok:
                        self._c_swapf.inc(1.0, (self._rep,))
                        self.reset_for_restart(req)
                        self.hold(req, "swap image corrupt or lost",
                                  boundary, now,
                                  site="swap_loss" if lost
                                  else "swap_corrupt")
                        converted += 1
                        continue
                keep.append(req)
            st.preempted = keep
        return converted

    # ------------------------------------------------------- load shedding
    def note_admitted(self, reqs: Iterable["Request"]) -> None:
        for req in reqs:
            self._queued_since.pop(req.rid, None)

    def shed_stalled(self, boundary: int, now: float) -> int:
        """Graceful degradation under sustained pressure: dead-letter any
        request queued (and inadmissible) for ``shed_after_boundaries``
        consecutive boundaries.  Disabled when the policy knob is None."""
        limit = self.policy.shed_after_boundaries
        if limit is None:
            return 0
        n = 0
        for st in self.rm._tenants.values():
            for lane in ("pending", "preempted"):
                keep: deque = deque()
                for req in getattr(st, lane):
                    first = self._queued_since.setdefault(req.rid,
                                                          boundary)
                    if boundary - first >= limit:
                        req.swap = None
                        self.dead_letter(
                            req, f"shed after {boundary - first} "
                            f"boundaries queued under pressure",
                            boundary, now, site="shed")
                        self._c_shed.inc(1.0, (self._rep,))
                        n += 1
                    else:
                        keep.append(req)
                setattr(st, lane, keep)
        return n

    # --------------------------------------------------- invariant checker
    def check_invariants(self, bt, seq_lens):
        """Audit the boundary state the dispatches are about to trust.
        Returns ``(per_request, global_violations)``: per-request entries
        are ``(request, why)`` pairs the engine quarantines (full
        restart — the state is suspect); global ledger drift cannot be
        attributed to one request and is recorded + surfaced in stats
        and the watchdog snapshot instead."""
        from repro.serving.paged_cache import TRASH_PAGE
        sched = self.sched
        alloc = sched.allocator
        pcfg = sched.pcfg
        bad: list[tuple["Request", str]] = []
        for slot, req in sorted(sched.running.items()):
            pages = [int(p) for p in (req.pages or [])]
            row = [int(p) for p in bt[slot]]
            if row[:len(pages)] != pages:
                bad.append((req, "block-table row diverged from owned "
                            "pages"))
            elif any(p != TRASH_PAGE for p in row[len(pages):]):
                bad.append((req, "block-table coverage beyond owned "
                            "pages"))
            elif any(alloc.refcount(p) < 1 for p in pages):
                bad.append((req, "owned page with zero refcount"))
            elif int(seq_lens[slot]) > len(pages) * pcfg.page_size:
                bad.append((req, "resident tokens beyond owned page "
                            "coverage"))
        glob: list[str] = []
        live = sum(r.charged for r in sched.running.values())
        total = sum(st.charged for st in self.rm._tenants.values())
        if live != total:
            glob.append(f"quota ledger drift: running charges {live} != "
                        f"tenant charges {total}")
        if alloc.n_free + alloc.n_held != pcfg.allocatable_pages:
            glob.append(f"page ledger drift: free {alloc.n_free} + held "
                        f"{alloc.n_held} != pool "
                        f"{pcfg.allocatable_pages}")
        for req, why in bad:
            self.invariant_violations.append(f"{req.rid!r}: {why}")
        self.invariant_violations.extend(glob)
        if bad or glob:
            self._c_inv.inc(float(len(bad) + len(glob)), (self._rep,))
        return bad, glob

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"quarantines": self.quarantines,
                "restarts": self.restarts,
                "swap_faults_detected": self.swap_faults_detected,
                "segment_dispatch_faults": self.segment_dispatch_faults,
                "shed": self.shed,
                "dead_lettered": len(self.dead),
                # structured per-request terminal records (site, tenant,
                # retries, checkpoint) — the bench/telemetry view of WHY
                # each dead letter died, not just how many did
                "dead_letter_records": [req.failure.record()
                                        for req in self.dead],
                "invariant_violations": list(self.invariant_violations)}


def diagnostic_snapshot(sched: "ContinuousBatchingScheduler",
                        recovery: RecoveryManager | None = None,
                        boundary: int | None = None,
                        **extra) -> dict:
    """Structured engine state for the watchdog (and debugging): queue
    depths, pool pressure, per-slot request state, recovery counters."""
    rm = sched.rm
    snap: dict = {
        "boundary": boundary,
        "free_pages": sched.allocator.n_free,
        "held_pages": sched.allocator.n_held,
        "free_slots": list(sched.free_slots),
        "queues": {name: {"pending": len(st.pending),
                          "preempted": len(st.preempted),
                          "deficit": st.deficit}
                   for name, st in sorted(rm._tenants.items())},
        "running": {int(slot): {"rid": req.rid, "tenant": req.tenant,
                                "n_pages": len(req.pages or []),
                                "n_tokens": len(req.tokens),
                                "stalled": req.stalled,
                                "protected": req.protected,
                                "n_retries": req.n_retries}
                    for slot, req in sorted(sched.running.items())},
        "stats": rm.stats(),
    }
    if recovery is not None:
        snap["recovery"] = recovery.stats()
        snap["quarantined"] = [
            {"rid": req.rid, "tenant": req.tenant,
             "release_boundary": b, "n_retries": req.n_retries,
             "has_image": req.swap is not None}
            for req, b in recovery._quarantine]
    snap.update(extra)
    return snap
