"""Replicated serving: N engine runs behind a health-checked front door.

MetaML's flow-level resilience story (bad candidate stages are detected
and the flow routes around them) extends one level up in the serving
stack: a single :class:`~repro.serving.engine.PagedServingEngine` run
already survives *intra-engine* faults (serving/recovery.py), but a
replica-level failure — the whole device state gone, the host loop
wedged — needs somewhere else to put the work.  This module provides
that somewhere else:

- A :class:`ServingCluster` holds ONE compiled engine and N
  :class:`~repro.serving.engine.EngineRun` replicas — each with its own
  page pool, block tables, tenant ledgers, and prefix trie — stepped
  round-robin at segment boundaries (single process, CPU dev box; the
  replication axis is state, not devices).
- A :class:`FrontDoor` routes each arrival with *prefix affinity*: the
  replica whose prefix trie already holds the longest piece of the
  request's prompt wins (``PrefixCache.lookup`` is a pure read, so
  probing every replica is free of side effects); ties fall back to
  least-loaded (most free pages, then fewest resident requests).
- A boundary-progress *health model*: a replica that misses
  ``suspect_after`` consecutive boundary heartbeats is SUSPECT,
  ``dead_after`` is DEAD; an :class:`EngineStalledError` from its
  watchdog is immediately DEAD.  DEAD replicas are permanently fenced —
  never stepped again — which is the cluster's no-double-completion
  guarantee.
- *Failover* reuses the PR-5/6 machinery wholesale: host swap images
  are device-agnostic (a restore scatters ``swap.host_k`` into freshly
  allocated pages — ``swap.pages`` is never read), so a preempted or
  quarantined request whose image passes its CRC migrates to a
  surviving replica through the ordinary preempted-restore lane, with
  a prefix-trie re-match on the new replica.  Requests without a
  salvageable image restart from scratch (greedy decode is
  deterministic, so the regenerated stream is bit-identical); work
  lost this way costs one retry, and exhausted retries dead-letter
  with a typed :class:`ReplicaLost`.
- Graceful :meth:`ServingCluster.drain` for rolling restarts: stop
  routing to the replica, evacuate every resident request as a
  verified host image, migrate them out, and :meth:`rejoin` later with
  a cold trie that re-warms through prefix-affinity misses.

Replica-level fault sites (``replica_crash``, ``replica_hang``,
``heartbeat_loss`` — :data:`~repro.serving.faults.REPLICA_SITES`) ride
the same seed-driven opportunity-counted FaultPlan as the engine sites:
the cluster probes each live replica once per round, so a chaos run
replays bit-exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.serving.engine import EngineRun, PagedServingEngine
from repro.serving.faults import FaultPlan, image_checksum
from repro.serving.observe import Observability
# re-exported for back-compat: HealthPolicy moved to serving/plan.py so a
# ServingPlan can carry the cluster shape without importing this module
from repro.serving.plan import HealthPolicy, ServingPlan
from repro.serving.recovery import (EngineStalledError, RecoveryPolicy,
                                    RequestFailed)
from repro.serving.scheduler import Request

# Replica lifecycle.  HEALTHY/SUSPECT step and accept routes; DRAINING
# is the transient inside drain(); DOWN is drained-and-out (rejoinable);
# DEAD is fenced forever (a rejoin under the same name is a fresh run).
HEALTHY = "HEALTHY"
SUSPECT = "SUSPECT"
DRAINING = "DRAINING"
DOWN = "DOWN"
DEAD = "DEAD"
_LIVE = (HEALTHY, SUSPECT)


@dataclasses.dataclass(frozen=True)
class ReplicaLost(RequestFailed):
    """Terminal record for a request that died *because its replica
    did*: the failover path ran out of retries or out of surviving
    replicas.  ``site`` carries the replica-level fault site that took
    the replica down; ``replica`` names it."""
    replica: str = "?"

    def record(self) -> dict:
        return {**super().record(), "replica": self.replica}


@dataclasses.dataclass
class Replica:
    """One replica's control-plane state; the data plane is ``run``."""
    name: str
    run: EngineRun
    state: str = HEALTHY
    missed: int = 0                     # consecutive heartbeat misses
    crashed: bool = False               # device state destroyed
    hung: bool = False                  # host loop wedged, state intact
    fenced: bool = False                # salvaged; never stepped again
    cause: str = "heartbeat_loss"       # site that took it down

    @property
    def live(self) -> bool:
        return self.state in _LIVE


class FrontDoor:
    """Prefix-affinity router over the cluster's replicas.

    Routing key, best first: longest trie prefix match for the prompt
    (affinity — the replica that already holds the K/V serves the
    request without re-prefilling it), then most free pages, then
    fewest resident requests, then index (deterministic ties).  Only
    HEALTHY replicas are candidates; SUSPECT ones are a fallback so a
    transiently-flapping cluster keeps admitting; DRAINING/DOWN/DEAD
    never route.  Returns None when nothing can take the request.
    """

    def __init__(self, replicas: list[Replica], obs=None):
        self.replicas = replicas
        obs = obs if obs is not None else Observability.disabled()
        # labeled by the TARGET replica; the historical totals read back
        # through the registry as thin views
        self._c_routed = obs.counter(
            "serving_frontdoor_routed_total",
            "requests routed, by target replica", ("replica",))
        self._c_aff = obs.counter(
            "serving_frontdoor_affinity_hits_total",
            "routes that hit a prefix-affinity match", ("replica",))

    @property
    def routed(self) -> int:
        return int(self._c_routed.total())

    @property
    def affinity_hits(self) -> int:
        return int(self._c_aff.total())

    def _affinity(self, rep: Replica, req: Request) -> int:
        pc = rep.run.sched.prefix_cache
        if pc is None:
            return 0
        return pc.lookup(req.prompt).n_tokens    # pure read

    def route(self, req: Request) -> Replica | None:
        cands = [r for r in self.replicas if r.state == HEALTHY]
        if not cands:
            cands = [r for r in self.replicas if r.state == SUSPECT]
        if not cands:
            return None
        scored = []
        for i, rep in enumerate(self.replicas):
            if rep not in cands:
                continue
            run = rep.run
            busy = len(run.sched.running) + len(run.sched.pending)
            scored.append((-self._affinity(rep, req),
                           -run.sched.allocator.n_free, busy, i, rep))
        scored.sort(key=lambda t: t[:4])
        aff, _free, _busy, _i, best = scored[0]
        self._c_routed.inc(1.0, (best.name,))
        if aff < 0:
            self._c_aff.inc(1.0, (best.name,))
        return best

    def stats(self) -> dict:
        return {"routed": self.routed,
                "affinity_hits": self.affinity_hits,
                "affinity_rate": (self.affinity_hits / self.routed
                                  if self.routed else 0.0)}


class ServingCluster:
    """N replicas of one compiled engine, stepped round-robin, with
    health-checked routing and cross-replica failover.

    One :class:`~repro.serving.faults.FaultPlan` covers the whole
    cluster: engine sites count opportunities inside each replica's
    ``step()`` (in round-robin order) and replica sites are probed here,
    once per live replica per round, in index order — so the combined
    schedule replays bit-exactly for a given request set.
    """

    @classmethod
    def from_plan(cls, model, params, plan: ServingPlan, *,
                  faults: FaultPlan | None = None,
                  recovery: RecoveryPolicy | None = None,
                  obs: Observability | None = None
                  ) -> "ServingCluster":
        """Deploy a :class:`~repro.serving.plan.ServingPlan`: build the
        compiled engine from the plan's cache geometry / prefill mode /
        tenant roster, then the cluster from its shape (``n_replicas``,
        ``health``).  The one-call counterpart of the searched-plan JSON
        the SERVE task emits."""
        engine = PagedServingEngine.from_plan(model, plan, faults=faults,
                                              recovery=recovery)
        return cls(engine, params, n_replicas=plan.n_replicas,
                   faults=faults, recovery=recovery, health=plan.health,
                   obs=obs)

    def __init__(self, engine: PagedServingEngine, params,
                 n_replicas: int = 2, *,
                 faults: FaultPlan | None = None,
                 recovery: RecoveryPolicy | None = None,
                 health: HealthPolicy | None = None,
                 obs: Observability | None = None):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.engine = engine
        self.params = params
        self.faults = faults if faults is not None else engine.faults
        self.recovery = recovery
        self.health = health if health is not None else HealthPolicy()
        t0 = time.perf_counter()
        self.clock = lambda: time.perf_counter() - t0
        # one metrics store + tracer for the whole cluster; each replica
        # run gets a for_replica() view that binds its label value
        if obs is None:
            obs = Observability.from_policy(engine.plan.observability)
        self.obs = obs
        self.tracer = obs.tracer
        self._c_failover = obs.counter(
            "serving_failover_total",
            "cross-replica request moves, by kind", ("kind",))
        self._c_health = obs.counter(
            "serving_replica_health_transitions_total",
            "replica state transitions", ("replica", "state"))
        self._c_miss = obs.counter(
            "serving_heartbeat_misses_total",
            "consecutive-miss ticks charged to a replica", ("replica",))
        # durable cluster: one root journal (the plan JSON + cluster-
        # level dead letters) and one subdirectory journal per replica,
        # all under plan.durability.journal_dir; RestartRecovery merges
        # the per-replica streams per request on replay
        self.journal = None
        pol = engine.plan.durability
        if pol.enabled:
            from repro.serving.journal import JournalWriter
            plan = dataclasses.replace(engine.plan,
                                       n_replicas=n_replicas)
            self.journal = JournalWriter.from_policy(pol, plan=plan,
                                                     faults=self.faults)
        self.replicas = [Replica(name=f"r{i}",
                                 run=self._fresh_run(f"r{i}"))
                         for i in range(n_replicas)]
        self.front_door = FrontDoor(self.replicas, obs=obs)
        self.dead: list[Request] = []   # cluster-level dead letters
        self.rounds = 0
        if self.faults is not None:
            # re-attach the taps at cluster scope: replica-level sites
            # fire here (outside any single run), so the trace hook's
            # boundary must be the round counter, not one run's boundary
            self.faults.metrics = obs.counter(
                "serving_fault_fires_total",
                "injected fault fires, by site", ("site",))
            if self.tracer is not None:
                self.faults.trace_hook = (
                    lambda site, k: self.tracer.event(
                        None, "FAULT", self.rounds, self.clock(),
                        site=site, opportunity=k))

    # failover totals as registry thin views
    @property
    def n_migrated(self) -> int:        # failovers via verified image
        return int(self._c_failover.value(("migrated",)))

    @property
    def n_restarted(self) -> int:       # failovers via full restart
        return int(self._c_failover.value(("restarted",)))

    @property
    def n_drained(self) -> int:         # graceful drain migrations
        return int(self._c_failover.value(("drained",)))

    def _fresh_run(self, name: str = "") -> EngineRun:
        journal = None
        pol = self.engine.plan.durability
        if pol.enabled and name:
            from repro.serving.journal import JournalWriter
            # a rejoin reopens the replica's existing subdirectory and
            # appends (the writer repairs any torn tail first)
            journal = JournalWriter.from_policy(pol, subdir=name,
                                                faults=self.faults)
        return EngineRun(self.engine, self.params, faults=self.faults,
                         recovery=self.recovery, clock=self.clock,
                         journal=journal,
                         obs=self.obs.for_replica(name or "r?"))

    def _replica(self, name: str) -> Replica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(f"no replica named {name!r}")

    # ----------------------------------------------------------- routing
    def submit(self, req: Request) -> bool:
        """Route one request; False means it dead-lettered unrouted."""
        rep = self.front_door.route(req)
        if rep is None:
            self._cluster_dead_letter(req, "no live replica to route to",
                                      site="no_replica", replica="-")
            return False
        rep.run.submit(req)
        return True

    # ------------------------------------------------------ health model
    def _set_state(self, rep: Replica, state: str) -> None:
        if rep.state != state:
            rep.state = state
            self._c_health.inc(1.0, (rep.name, state))

    def _beat(self, rep: Replica) -> None:
        rep.missed = 0
        if rep.state == SUSPECT:
            self._set_state(rep, HEALTHY)

    def _miss(self, rep: Replica) -> None:
        rep.missed += 1
        self._c_miss.inc(1.0, (rep.name,))
        if rep.missed >= self.health.dead_after:
            self._set_state(rep, DEAD)
        elif rep.missed >= self.health.suspect_after:
            self._set_state(rep, SUSPECT)

    # -------------------------------------------------------- one round
    def step_round(self) -> bool:
        """Step every live replica one boundary, update health, and
        salvage any replica that went DEAD.  Returns True when some
        replica made boundary progress (ran a segment / admitted)."""
        self.rounds += 1
        progress = False
        for rep in self.replicas:
            if not rep.live or rep.fenced:
                continue
            if not (rep.crashed or rep.hung) and self.faults is not None:
                # probe both sites every round a replica is actually
                # stepping — opportunity counts stay replayable
                if self.faults.should_fire("replica_crash"):
                    rep.crashed, rep.cause = True, "replica_crash"
                if self.faults.should_fire("replica_hang") \
                        and not rep.crashed:
                    rep.hung, rep.cause = True, "replica_hang"
            if rep.crashed or rep.hung:
                self._miss(rep)         # not stepping: heartbeats cease
                continue
            try:
                outcome = rep.run.step()
                if outcome == "idle" and rep.run.has_work:
                    # queued work that cannot admit: tick this replica's
                    # own watchdog rather than busy-spin (mirrors the
                    # single-engine run loop)
                    rep.run.note_stall()
            except EngineStalledError:
                self._set_state(rep, DEAD)
                rep.cause = "watchdog"
                continue
            if outcome != "idle":
                progress = True
            if self.faults is not None \
                    and self.faults.should_fire("heartbeat_loss"):
                self._miss(rep)         # dropped beat, stepping intact
            else:
                self._beat(rep)
        for rep in self.replicas:
            if rep.state == DEAD and not rep.fenced:
                self._salvage(rep)
        return progress

    # ---------------------------------------------------------- failover
    def _scrub(self, req: Request) -> None:
        """Strip every per-replica residue off a migrating request: the
        slot, pages, billing, and sharing state all referenced the dead
        replica's pool and mean nothing on the target (its admission
        re-plans them, including the trie re-match)."""
        req.slot = None
        req.pages = None
        req.charged = 0
        req.shared_tokens = 0
        req.shared_pages = 0
        req.cow_src = None
        req.cow_dst = None
        req.restore_blocks = (0, 0)
        req.stalled = False
        req.protected = False

    def _image_intact(self, req: Request) -> bool:
        sw = req.swap
        if sw is None or sw.host_k is None or sw.host_v is None:
            return False
        return sw.checksum is None \
            or sw.checksum == image_checksum(sw.host_k, sw.host_v)

    def _cluster_dead_letter(self, req: Request, reason: str, *,
                             site: str, replica: str) -> None:
        req.swap = None
        req.failure = ReplicaLost(rid=req.rid, tenant=req.tenant,
                                  reason=reason, boundary=self.rounds,
                                  retries=req.n_retries, site=site,
                                  ckpt_tokens=req.ckpt_tokens,
                                  replica=replica)
        req.t_done = self.clock()
        self.dead.append(req)
        if self.journal is not None:
            self.journal.dead_letter(req.failure.record())

    def _salvage(self, rep: Replica) -> None:
        """Fence a DEAD replica and fail its requests over.  Host-side
        state survives the death of device state: queued/quarantined
        requests keep their swap images (CRC-verified here, exactly
        once); running requests lost their pages — and, without an
        image, their generated tokens, costing them a retry."""
        rep.fenced = True
        run = rep.run
        reqs = [run.sched.running[s] for s in sorted(run.sched.running)]
        reqs += run.sched.rm.drain_queued()
        reqs += run.rec.drain_quarantined()
        for req in reqs:
            had_work = bool(req.tokens)
            if req.swap is not None:
                if self._image_intact(req):
                    req.swap.verified = True
                else:
                    req.swap = None     # corrupt/lost: fall through
            self._scrub(req)
            if req.swap is None and had_work:
                # committed work is gone; the restart burns a retry
                req.tokens = []
                req.ckpt_tokens = 0
                req.n_retries += 1
                if req.n_retries > run.policy.max_retries:
                    self._cluster_dead_letter(
                        req, f"retries exhausted after loss of replica "
                             f"{rep.name}", site=rep.cause,
                        replica=rep.name)
                    continue
            elif req.swap is None:
                req.tokens = []
                req.ckpt_tokens = 0
            target = self.front_door.route(req)
            if target is None:
                self._cluster_dead_letter(
                    req, f"no surviving replica after loss of "
                         f"{rep.name}", site=rep.cause, replica=rep.name)
                continue
            target.run.sched.rm.requeue(req)
            kind = "migrated" if req.swap is not None else "restarted"
            self._c_failover.inc(1.0, (kind,))
            if self.tracer is not None:
                self.tracer.event(req.rid, "MIGRATE", self.rounds,
                                  self.clock(), src=rep.name,
                                  dst=target.name, kind=kind,
                                  cause=rep.cause)

    # ------------------------------------------------- rolling restarts
    def drain(self, name: str) -> int:
        """Gracefully take a replica out: stop routing to it, evacuate
        every resident request as a verified host image, migrate them to
        the survivors (no retry cost — nothing was lost), and leave the
        replica DOWN, ready to :meth:`rejoin`.  Returns the number of
        requests moved."""
        rep = self._replica(name)
        if not rep.live:
            raise ValueError(f"cannot drain replica {name!r} in state "
                             f"{rep.state}")
        self._set_state(rep, DRAINING)
        moved = rep.run.evacuate()
        self._set_state(rep, DOWN)
        for req in moved:
            if req.swap is not None and self._image_intact(req):
                req.swap.verified = True
            elif req.swap is not None:
                req.swap = None
                req.tokens = []
                req.ckpt_tokens = 0
            self._scrub(req)
            target = self.front_door.route(req)
            if target is None:
                self._cluster_dead_letter(
                    req, f"no replica to absorb drain of {name}",
                    site="drain", replica=name)
                continue
            target.run.sched.rm.requeue(req)
            self._c_failover.inc(1.0, ("drained",))
            if self.tracer is not None:
                self.tracer.event(req.rid, "MIGRATE", self.rounds,
                                  self.clock(), src=name,
                                  dst=target.name, kind="drained",
                                  cause="drain")
        return len(moved)

    def close_journals(self) -> None:
        """Flush + close every journal writer (root and per-replica).
        A no-op without durability, and after an injected crash (the
        crashed writer is already closed without flushing)."""
        if self.journal is not None:
            self.journal.close()
        for rep in self.replicas:
            if rep.run.journal is not None:
                rep.run.journal.close()

    def rejoin(self, name: str) -> None:
        """Bring a DOWN (or replaced-DEAD) replica back with a fresh
        run: empty pool, cold prefix trie (it re-warms through
        prefix-affinity misses), clean health."""
        rep = self._replica(name)
        if rep.live:
            raise ValueError(f"replica {name!r} is already live")
        if rep.run.journal is not None:
            rep.run.journal.close()
        rep.run = self._fresh_run(rep.name)
        self._set_state(rep, HEALTHY)
        rep.missed = 0
        rep.crashed = rep.hung = rep.fenced = False
        rep.cause = "heartbeat_loss"

    def kill(self, name: str) -> None:
        """Deterministically crash a replica (tests/benches): it goes
        through the same detect → fence → salvage path an injected
        ``replica_crash`` does."""
        rep = self._replica(name)
        rep.crashed, rep.cause = True, "replica_crash"

    # ------------------------------------------------------------ driver
    def run(self, requests: list[Request],
            on_round: Callable[["ServingCluster", int], None]
            | None = None) -> dict:
        """Serve ``requests`` (honoring arrival offsets) through the
        front door to completion across the replicas.  ``on_round`` runs
        after every round — the hook tests/benches use to kill, drain,
        or rejoin replicas mid-burst."""
        queue = sorted(requests, key=lambda q: q.arrival)
        nxt = 0
        while nxt < len(queue) or any(r.live and r.run.has_work
                                      for r in self.replicas):
            now = self.clock()
            while nxt < len(queue) and queue[nxt].arrival <= now:
                self.submit(queue[nxt])
                nxt += 1
            progress = self.step_round()
            if on_round is not None:
                on_round(self, self.rounds)
            if not progress and nxt < len(queue) \
                    and not any(r.live and r.run.has_work
                                for r in self.replicas):
                wait = queue[nxt].arrival - self.clock()
                if wait > 0:
                    time.sleep(wait)
        out = self.stats()
        pol = self.obs.policy
        if self.obs.enabled and pol is not None and pol.export_dir:
            out["exports"] = self.obs.export(pol.export_dir)
        return out

    # ------------------------------------------------------------- stats
    @property
    def finished(self) -> list[Request]:
        """Completed requests across all replicas (including fenced ones
        — completion before death still counts)."""
        out: list[Request] = []
        for rep in self.replicas:
            out.extend(rep.run.sched.finished)
        return out

    @property
    def dead_lettered(self) -> list[Request]:
        """Dead letters across replicas plus cluster-level ReplicaLost."""
        out: list[Request] = []
        for rep in self.replicas:
            out.extend(rep.run.rec.dead)
        out.extend(self.dead)
        return out

    def stats(self) -> dict[str, Any]:
        dead = self.dead_lettered
        out = {"n_replicas": len(self.replicas),
               "rounds": self.rounds,
               "n_finished": len(self.finished),
               "n_dead_lettered": len(dead),
               "n_migrated": self.n_migrated,
               "n_restarted": self.n_restarted,
               "n_drained": self.n_drained,
               "replicas": {r.name: {"state": r.state,
                                     "missed": r.missed,
                                     "fenced": r.fenced,
                                     "n_finished":
                                         len(r.run.sched.finished),
                                     "n_segments": r.run.n_segments}
                            for r in self.replicas},
               "front_door": self.front_door.stats(),
               "dead_letter_records": [r.failure.record() for r in dead
                                       if r.failure is not None],
               "metrics": self.obs.summary()}
        if self.faults is not None:
            out["faults"] = self.faults.summary()
        return out
