"""Serving observability: metrics registry, request tracing, exporters.

Zero-dependency telemetry substrate for the serving stack — the one
measurement path shared by benches, the SERVE replay scorer, and the
per-module ``stats()`` views:

- **MetricsRegistry** — typed counters, gauges, and fixed-exponential-
  bucket histograms with positional label sets (replica, tenant, site).
  Handles are idempotent by name (two modules asking for the same
  counter share one series table, which is how the scheduler's
  dead-letter increments and the ResourceManager's ``dead_letters``
  property stay one number).  ``snapshot()``/``delta()`` give JSON-safe
  reads; ``to_prometheus()`` renders the text exposition format.
- **Tracer** — a flat, append-only event log forming per-request span
  trees over the engine's boundary protocol
  (SUBMIT → ADMIT → SEGMENT* → {PREEMPT/STALL/QUARANTINE/RETRY/
  MIGRATE}* → COMPLETE | DEAD_LETTER).  Every event carries the
  boundary index and the injectable-clock timestamp; ``sequence()``
  drops the timestamps, so traces from seeded ``FaultPlan`` runs are
  bit-reproducible modulo wall-clock.
- **Observability** — the facade the engine/cluster/scheduler thread
  through.  Counters are *always* live (they back the ``stats()`` thin
  views even when telemetry is off); histograms, gauges, the tracer,
  and file exports only exist when the policy enables them — a
  disabled probe costs one attribute lookup against ``NULL_METRIC`` or
  one ``is not None`` test, and allocates nothing.

``ObservabilityPolicy`` (the plan knob group) lives in
``serving/plan.py`` beside the other policy dataclasses; this module
only duck-types it (``enabled`` / ``histogram_buckets`` / ``trace`` /
``export_dir``) so the plan never has to import machinery.
"""

from __future__ import annotations

import bisect
import json
import os

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_METRIC",
    "Observability", "SpanEvent", "Tracer", "exponential_buckets",
    "render_summary",
]


def exponential_buckets(start: float = 1e-4, factor: float = 2.0,
                        count: int = 18) -> tuple:
    """Upper bucket bounds ``start * factor**k`` for k in [0, count).

    The default grid spans 100 us .. ~13 s — the serving latency range
    from a single decode-token dispatch to a heavily backed-off retry.
    A final implicit +Inf bucket catches everything above.
    """
    if start <= 0 or factor <= 1.0 or count < 1:
        raise ValueError(
            f"buckets need start>0, factor>1, count>=1; got "
            f"({start}, {factor}, {count})")
    return tuple(start * factor ** k for k in range(count))


DEFAULT_BUCKETS = exponential_buckets()


class _NullMetric:
    """Shared do-nothing handle: the disabled-mode probe target.

    Every mutating/reading method exists so call sites never branch —
    a disabled probe is one attribute lookup plus a no-op call, and
    allocates nothing (pinned by tests/test_observe.py).
    """

    __slots__ = ()

    def inc(self, v=1.0, labels=()):
        pass

    def dec(self, v=1.0, labels=()):
        pass

    def set(self, v, labels=()):
        pass

    def observe(self, v, labels=()):
        pass

    def value(self, labels=()):
        return 0.0

    def total(self, **match):
        return 0.0


NULL_METRIC = _NullMetric()


class _Metric:
    __slots__ = ("name", "help", "labels", "series")
    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: tuple = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        # label-value tuple (positional, matching self.labels) -> state
        self.series: dict = {}

    def _match_indices(self, match: dict) -> dict:
        try:
            return {self.labels.index(k): v for k, v in match.items()}
        except ValueError:
            raise ValueError(
                f"{self.name} has labels {self.labels}, not "
                f"{tuple(match)}") from None

    def value(self, labels: tuple = ()):
        return self.series.get(labels, 0.0)

    def total(self, **match) -> float:
        """Sum over series whose named labels equal the given values."""
        if not match:
            return float(sum(self.series.values()))
        idx = self._match_indices(match)
        return float(sum(
            v for key, v in self.series.items()
            if all(key[i] == want for i, want in idx.items())))


class Counter(_Metric):
    """Monotonic counter; one float per label-value tuple."""

    __slots__ = ()
    kind = "counter"

    def inc(self, v: float = 1.0, labels: tuple = ()):
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.series[labels] = self.series.get(labels, 0.0) + v


class Gauge(_Metric):
    """Set/inc/dec instantaneous value per label-value tuple."""

    __slots__ = ()
    kind = "gauge"

    def set(self, v: float, labels: tuple = ()):
        self.series[labels] = float(v)

    def inc(self, v: float = 1.0, labels: tuple = ()):
        self.series[labels] = self.series.get(labels, 0.0) + v

    def dec(self, v: float = 1.0, labels: tuple = ()):
        self.inc(-v, labels)


class Histogram(_Metric):
    """Fixed-exponential-bucket histogram.

    Per label-value tuple: ``[counts, sum, count]`` where ``counts``
    has ``len(buckets) + 1`` slots — one per finite upper bound plus
    the +Inf catch-all.  Bucket ``i`` counts observations ``v`` with
    ``buckets[i-1] < v <= buckets[i]`` (Prometheus ``le`` semantics).
    """

    __slots__ = ("buckets",)
    kind = "histogram"

    def __init__(self, name, help="", labels=(),
                 buckets: tuple = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        buckets = tuple(float(b) for b in buckets)
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"histogram {name} buckets must be non-empty and "
                f"strictly increasing: {buckets}")
        self.buckets = buckets

    def observe(self, v: float, labels: tuple = ()):
        s = self.series.get(labels)
        if s is None:
            s = self.series[labels] = \
                [[0] * (len(self.buckets) + 1), 0.0, 0]
        s[0][bisect.bisect_left(self.buckets, v)] += 1
        s[1] += v
        s[2] += 1

    def count(self, labels: tuple = ()) -> int:
        s = self.series.get(labels)
        return s[2] if s is not None else 0

    def sum(self, labels: tuple = ()) -> float:
        s = self.series.get(labels)
        return s[1] if s is not None else 0.0

    def _merged_counts(self, labels):
        if labels is not None:
            s = self.series.get(labels)
            return list(s[0]) if s is not None else None
        merged = None
        for s in self.series.values():
            if merged is None:
                merged = list(s[0])
            else:
                merged = [a + b for a, b in zip(merged, s[0])]
        return merged

    def percentile(self, q: float, labels: tuple | None = None) -> float:
        """Bucket-interpolated q-th percentile (labels=None merges all
        series).  Values past the top finite bound clamp to it."""
        counts = self._merged_counts(labels)
        if not counts or not sum(counts):
            return 0.0
        rank = (q / 100.0) * sum(counts)
        cum, lo = 0.0, 0.0
        for i, ub in enumerate(self.buckets):
            c = counts[i]
            if c and cum + c >= rank:
                return lo + max(rank - cum, 0.0) / c * (ub - lo)
            cum += c
            lo = ub
        return self.buckets[-1]

    # value() on a histogram is its count: keeps total(**match) usable
    def value(self, labels: tuple = ()):
        return self.count(labels)

    def total(self, **match) -> float:
        if not match:
            return float(sum(s[2] for s in self.series.values()))
        idx = self._match_indices(match)
        return float(sum(
            s[2] for key, s in self.series.items()
            if all(key[i] == want for i, want in idx.items())))


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _label_str(names: tuple, values: tuple, extra: tuple = ()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class MetricsRegistry:
    """Name-keyed metric store; handles are idempotent per name."""

    def __init__(self, histogram_buckets: tuple = ()):
        self._metrics: dict = {}
        self.histogram_buckets = \
            tuple(histogram_buckets) or DEFAULT_BUCKETS

    def _get(self, cls, name, help, labels, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labels != tuple(labels):
                raise ValueError(
                    f"metric {name} already registered as {m.kind}"
                    f"{m.labels}; asked for {cls.kind}{tuple(labels)}")
            return m
        m = self._metrics[name] = cls(name, help, tuple(labels), **kw)
        return m

    def counter(self, name, help="", labels=()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=(),
                  buckets: tuple | None = None) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         buckets=tuple(buckets) if buckets
                         else self.histogram_buckets)

    def metrics(self) -> list:
        return [self._metrics[n] for n in sorted(self._metrics)]

    def snapshot(self) -> dict:
        """JSON-safe point-in-time read of every series."""
        out = {}
        for m in self.metrics():
            entry = {"kind": m.kind, "help": m.help,
                     "labels": list(m.labels)}
            if m.kind == "histogram":
                entry["buckets"] = list(m.buckets)
                entry["series"] = [
                    {"labels": list(k), "counts": list(s[0]),
                     "sum": s[1], "count": s[2]}
                    for k, s in sorted(m.series.items())]
            else:
                entry["series"] = [{"labels": list(k), "value": v}
                                   for k, v in sorted(m.series.items())]
            out[m.name] = entry
        return out

    def delta(self, prev: dict) -> dict:
        """Snapshot minus a previous ``snapshot()`` (counters and
        histograms subtract; gauges report their current value)."""
        cur = self.snapshot()
        for name, entry in cur.items():
            if entry["kind"] == "gauge" or name not in prev:
                continue
            old = {tuple(s["labels"]): s
                   for s in prev[name]["series"]}
            for s in entry["series"]:
                o = old.get(tuple(s["labels"]))
                if o is None:
                    continue
                if entry["kind"] == "histogram":
                    s["counts"] = [a - b for a, b in
                                   zip(s["counts"], o["counts"])]
                    s["sum"] -= o["sum"]
                    s["count"] -= o["count"]
                else:
                    s["value"] -= o["value"]
        return cur

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, deterministically
        ordered (metrics by name, series by label values)."""
        lines = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key in sorted(m.series):
                if m.kind == "histogram":
                    counts, total, n = m.series[key]
                    cum = 0
                    for ub, c in zip(m.buckets, counts):
                        cum += c
                        lines.append(
                            f"{m.name}_bucket"
                            f"{_label_str(m.labels, key, (('le', _fmt(ub)),))}"
                            f" {cum}")
                    lines.append(
                        f"{m.name}_bucket"
                        f"{_label_str(m.labels, key, (('le', '+Inf'),))}"
                        f" {n}")
                    lines.append(f"{m.name}_sum"
                                 f"{_label_str(m.labels, key)}"
                                 f" {_fmt(total)}")
                    lines.append(f"{m.name}_count"
                                 f"{_label_str(m.labels, key)} {n}")
                else:
                    lines.append(f"{m.name}"
                                 f"{_label_str(m.labels, key)}"
                                 f" {_fmt(m.series[key])}")
        return "\n".join(lines) + ("\n" if lines else "")


def render_summary(registry: MetricsRegistry) -> dict:
    """Compact JSON-safe table for bench rows and ``result()``:
    counter/gauge totals plus p50/p95/mean per histogram."""
    counters, gauges, latency = {}, {}, {}
    for m in registry.metrics():
        if m.kind == "counter":
            if m.series:
                counters[m.name] = m.total()
        elif m.kind == "gauge":
            if m.series:
                gauges[m.name] = m.total()
        else:
            n = m.total()
            if n:
                latency[m.name] = {
                    "count": int(n),
                    "mean": sum(s[1] for s in m.series.values()) / n,
                    "p50": m.percentile(50),
                    "p95": m.percentile(95),
                }
    return {"counters": counters, "gauges": gauges,
            "histograms": latency}


# ------------------------------------------------------------- tracing
class SpanEvent:
    """One request-lifecycle event: ``kind`` at ``boundary``/``t``.

    ``detail`` holds only deterministic payload (sites, reasons, page
    counts — never wall-clock durations), so ``Tracer.sequence()``
    is bit-reproducible for seeded fault plans.
    """

    __slots__ = ("rid", "kind", "boundary", "t", "detail")

    def __init__(self, rid, kind: str, boundary: int, t: float,
                 detail: dict):
        self.rid = rid
        self.kind = kind
        self.boundary = boundary
        self.t = t
        self.detail = detail

    def record(self) -> dict:
        return {"rid": self.rid, "kind": self.kind,
                "boundary": self.boundary, "t": self.t,
                "detail": self.detail}

    def __repr__(self):
        return (f"SpanEvent(rid={self.rid}, kind={self.kind!r}, "
                f"boundary={self.boundary}, t={self.t:.6f}, "
                f"detail={self.detail})")


# event kind -> lifecycle phase it opens (span_tree delimiter set)
_PHASE_OF = {
    "SUBMIT": "queued", "ADMIT": "running", "PREEMPT": "swapped",
    "STALL": "stalled", "QUARANTINE": "quarantined", "RETRY": "queued",
    "MIGRATE": "migrating", "COMPLETE": "done", "DEAD_LETTER": "dead",
}


class Tracer:
    """Append-only event log; per-request views are derived reads."""

    def __init__(self):
        self.events: list = []

    def event(self, rid, kind: str, boundary: int, t: float, **detail):
        self.events.append(SpanEvent(rid, kind, boundary, t, detail))

    def trace(self, rid) -> list:
        return [e for e in self.events if e.rid == rid]

    def rids(self) -> list:
        seen: dict = {}
        for e in self.events:
            if e.rid is not None:
                seen.setdefault(e.rid, None)
        return list(seen)

    def sequence(self) -> list:
        """The deterministic view: every event minus timestamps.  Two
        seeded chaos runs must produce equal sequences."""
        return [(e.rid, e.kind, e.boundary,
                 tuple(sorted(e.detail.items())))
                for e in self.events]

    def span_tree(self, rid) -> list:
        """Group one request's events into lifecycle spans.  Each
        phase-opening kind (SUBMIT/ADMIT/PREEMPT/...) closes the
        previous span; non-delimiter kinds (SEGMENT, ADMIT_FAIL,
        SWAP_FAULT, ...) attach to the current one."""
        spans: list = []
        cur = None
        for e in self.trace(rid):
            phase = _PHASE_OF.get(e.kind)
            if phase is not None:
                if cur is not None:
                    cur["t_end"] = e.t
                    cur["boundary_end"] = e.boundary
                cur = {"phase": phase, "t_start": e.t,
                       "t_end": None, "boundary_start": e.boundary,
                       "boundary_end": None, "events": []}
                spans.append(cur)
            if cur is not None:
                cur["events"].append(e.kind)
        return spans

    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e.record(), sort_keys=True,
                                   separators=(",", ":")) + "\n")
        return path


# -------------------------------------------------------------- facade
class Observability:
    """What the serving modules actually hold.

    Counters stay live regardless of the policy — they are the storage
    behind the ``stats()`` thin views.  Histograms and gauges come
    back as ``NULL_METRIC`` and ``tracer`` is ``None`` when disabled,
    so the hot path pays one attribute lookup (or one ``is not None``
    test) per probe and never allocates.

    ``for_replica`` binds a replica name while *sharing* the registry
    and tracer — a cluster's N replicas feed one store, and each
    replica's views filter on its own label value.
    """

    def __init__(self, policy=None, replica: str = ""):
        self.policy = policy
        self.enabled = bool(policy is not None
                            and getattr(policy, "enabled", False))
        buckets = tuple(getattr(policy, "histogram_buckets", ()) or ()) \
            if policy is not None else ()
        self.registry = MetricsRegistry(histogram_buckets=buckets)
        self.tracer = Tracer() if self.enabled and \
            getattr(policy, "trace", True) else None
        self.replica = replica

    @classmethod
    def disabled(cls) -> "Observability":
        """A fresh all-off instance (never a singleton: independent
        engines/tests must not share one counter store)."""
        return cls()

    @classmethod
    def from_policy(cls, policy) -> "Observability":
        return cls(policy=policy)

    def for_replica(self, name: str) -> "Observability":
        clone = object.__new__(Observability)
        clone.policy = self.policy
        clone.enabled = self.enabled
        clone.registry = self.registry       # shared
        clone.tracer = self.tracer           # shared
        clone.replica = name
        return clone

    # counters are always real: they back the stats() thin views
    def counter(self, name, help="", labels=()) -> Counter:
        return self.registry.counter(name, help, labels)

    # gauges/histograms only exist when telemetry is on
    def gauge(self, name, help="", labels=()):
        return self.registry.gauge(name, help, labels) \
            if self.enabled else NULL_METRIC

    def histogram(self, name, help="", labels=(), buckets=None):
        return self.registry.histogram(name, help, labels,
                                       buckets=buckets) \
            if self.enabled else NULL_METRIC

    def summary(self) -> dict:
        return render_summary(self.registry)

    def export(self, out_dir: str) -> dict:
        """Write ``metrics.prom`` (+ ``trace.jsonl`` when tracing) to
        ``out_dir``; returns the written paths."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {"metrics": os.path.join(out_dir, "metrics.prom")}
        with open(paths["metrics"], "w") as f:
            f.write(self.registry.to_prometheus())
        if self.tracer is not None:
            paths["trace"] = self.tracer.to_jsonl(
                os.path.join(out_dir, "trace.jsonl"))
        return paths
