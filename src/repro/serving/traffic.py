"""TrafficProfile: seeded synthetic serving workloads + the replay scorer.

The SERVE design-flow task (tasks/serve.py) needs a *fitness function*
for candidate :class:`~repro.serving.plan.ServingPlan`\\ s, and the bench
suite (benchmarks/bench_serve.py) needs reproducible request streams.
Both are the same thing: a :class:`TrafficProfile` — request count,
arrival process, shared-prefix ratio, tenant mix, prompt/gen lengths,
one seed — expanded deterministically into
:class:`~repro.serving.scheduler.Request` lists by :meth:`requests`.
``bench_serve``'s Poisson rows and the SERVE task's stage-2 scorer call
the same entry point, so the flow's objective is measured on exactly the
workload the bench gates.

:func:`replay` runs one profile through an engine built from a plan and
returns the uptune-style split the staged search prunes on:

- *intermediate features* (cheap, behavioral, deterministic for a burst
  profile): admission latency percentiles, preemptions, peak resident
  pages, allocation failures, segment count, dead letters;
- the *objective*: aggregate generated tokens per wall second, with
  feasibility = every request finished and nothing dead-lettered.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.serving.resources import DEFAULT_TENANT


@dataclasses.dataclass(frozen=True)
class TrafficProfile:
    """One synthetic serving workload, fully determined by its fields.

    ``arrival_rate`` (requests/s) drives a seeded Poisson arrival
    process; ``None`` means a burst (everything arrives at t=0 — also
    the fully deterministic mode, since no wall-clock sleeping is
    involved).  ``prefix_share`` is the fraction of the prompt shared
    verbatim by every request (aligned down to page granularity by
    :meth:`requests`, mirroring real system prompts).  ``tenant_mix``
    assigns tenants by seeded weighted sampling."""
    name: str = "smoke"
    n_requests: int = 8
    arrival_rate: float | None = None     # req/s; None = burst at t=0
    prefix_share: float = 0.0             # fraction of prompt shared
    prompt_len: int = 32
    max_new_tokens: int = 16
    tenant_mix: tuple[tuple[str, float], ...] = ()   # (tenant, weight)
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if not 0.0 <= self.prefix_share < 1.0:
            raise ValueError("need 0 <= prefix_share < 1")

    # ------------------------------------------------------- (de)serialize
    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tenant_mix"] = [list(t) for t in self.tenant_mix]
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TrafficProfile":
        """Unknown keys dropped, missing keys defaulted — the same
        forward-compat contract as ServingPlan/PagedCacheConfig."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        if "tenant_mix" in kw:
            kw["tenant_mix"] = tuple((str(n), float(w))
                                     for n, w in kw["tenant_mix"])
        return cls(**kw)

    def scaled(self, frac: float) -> "TrafficProfile":
        """A cheaper copy for the staged search's stage 1: same arrival
        process, same mix, same seed, ``frac`` of the requests and of
        the generation length (floored so the workload stays
        non-trivial)."""
        return dataclasses.replace(
            self,
            name=f"{self.name}@{frac:g}",
            n_requests=max(1, int(round(self.n_requests * frac))),
            max_new_tokens=max(2, int(round(self.max_new_tokens * frac))))

    # ------------------------------------------------------------ expand
    def requests(self, vocab_size: int, *, page_size: int = 1) -> list:
        """Deterministic request list for this profile.

        Prompts come from the same Zipf-bigram token stream the benches
        use (data/synthetic.lm_tokens, keyed on ``seed``); the shared
        prefix overwrites the head of every prompt with request 0's,
        aligned down to ``page_size`` (the prefix cache's match
        granule).  Arrivals are a seeded exponential cumsum when
        ``arrival_rate`` is set; tenants are seeded weighted draws from
        ``tenant_mix``."""
        from repro.data.synthetic import lm_tokens
        from repro.serving.scheduler import Request

        n, pl = self.n_requests, self.prompt_len
        prompts = np.asarray(
            lm_tokens(n * pl, vocab_size, seed=self.seed)
        ).reshape(n, pl).astype(np.int32)
        if self.prefix_share > 0.0 and page_size >= 1:
            prefix_len = int(self.prefix_share * pl) // page_size \
                * page_size
            prefix_len = min(prefix_len, pl - 1)  # keep >= 1 suffix token
            if prefix_len > 0:
                prompts[:, :prefix_len] = prompts[0, :prefix_len]
        arrivals = [0.0] * n
        rng = np.random.default_rng(self.seed + 1)
        if self.arrival_rate:
            arrivals = np.cumsum(
                rng.exponential(1.0 / self.arrival_rate, size=n)).tolist()
        tenants = [DEFAULT_TENANT] * n
        if self.tenant_mix:
            names = [t for t, _ in self.tenant_mix]
            w = np.asarray([w for _, w in self.tenant_mix], float)
            tenants = [str(t) for t in
                       rng.choice(names, size=n, p=w / w.sum())]
        return [Request(rid=i, prompt=prompts[i],
                        max_new_tokens=self.max_new_tokens,
                        arrival=arrivals[i], tenant=tenants[i])
                for i in range(n)]


def replay(model, params, plan, profile: TrafficProfile, *,
           warm: int = 1) -> tuple[bool, float, dict[str, Any]]:
    """Score one :class:`~repro.serving.plan.ServingPlan` on one profile.

    Builds the engine via ``PagedServingEngine.from_plan``, runs ``warm``
    untimed passes (compile + steady-state shapes), then one measured
    pass.  Returns the ``(feasible, objective, features)`` triple the
    search primitives consume: objective is aggregate generated tokens
    per wall second; features are the cheap intermediate signals
    (admission latency, preemptions, peak pages, ...) stage 1 prunes on.
    Replication is a deployment knob, not a fitness term — scoring runs
    a single engine regardless of ``plan.n_replicas``.

    Isolation: a candidate plan that stalls its engine (pathological
    geometry under the profile) scores infeasible instead of raising —
    and win or lose, every request's engine residue (host swap images,
    page lists into the candidate's pool) is scrubbed in a ``finally``,
    so a faulted stage-1 replay in ``staged_search`` can never leak
    pool state into the next candidate's measurement.
    """
    from repro.serving.engine import PagedServingEngine
    from repro.serving.recovery import EngineStalledError

    engine = PagedServingEngine.from_plan(model, plan)
    vocab = int(model.cfg.vocab_size)
    ps = plan.cache.page_size
    warm_reqs: list = []
    reqs = profile.requests(vocab, page_size=ps)
    try:
        for _ in range(max(0, warm)):
            warm_reqs = profile.requests(vocab, page_size=ps)
            engine.run(warm_reqs, params)
        stats = engine.run(reqs, params)
    except EngineStalledError as e:
        return False, 0.0, {"profile": profile.name, "stalled": True,
                            "reason": str(e)}
    finally:
        for r in reqs + warm_reqs:
            r.swap = None
            r.pages = None
            r.slot = None
            r.restore_blocks = (0, 0)
        del engine
    # stage-1 feature vector reads from the telemetry layer — the
    # measured per-request records in stats["requests"] and the
    # registry-backed counters — not from Request fields
    adm = [rec["queue_wait_s"] for rec in stats["requests"]
           if rec["queue_wait_s"] is not None]
    tokens = sum(rec["n_tokens"] for rec in stats["requests"])
    feats = {
        "profile": profile.name,
        "admission_p50_s": float(np.percentile(adm, 50)) if adm else 0.0,
        "admission_p95_s": float(np.percentile(adm, 95)) if adm else 0.0,
        "preemptions": int(stats["preemptions"]),
        "peak_pages": int(plan.cache.allocatable_pages
                          - stats["free_low_water"]),
        "alloc_failures": int(stats["alloc_failures"]),
        "n_segments": int(stats["n_segments"]),
        "dead_letters": int(stats["n_dead_lettered"]),
        "tokens": int(tokens),
        "wall_s": float(stats["wall_s"]),
        "decode_s": float(stats["decode_s"]),
    }
    ok = stats["n_dead_lettered"] == 0 \
        and stats["n_finished"] == len(reqs)
    objective = tokens / max(stats["wall_s"], 1e-9)
    return ok, objective, feats


def make_replay_scorer(model, params, profile: TrafficProfile, *,
                       stage1_frac: float = 0.5, warm: int = 1):
    """The SERVE task's default two-stage fitness function: stage 1
    replays a :meth:`TrafficProfile.scaled` shrink of the profile
    (cheap — fewer requests, shorter generations), stage 2 the full
    profile.  Returns ``scorer(plan, stage) -> (ok, objective, info)``.
    """
    cheap = profile.scaled(stage1_frac)

    def scorer(plan, stage: int):
        prof = cheap if stage == 1 else profile
        return replay(model, params, plan, prof, warm=warm)

    return scorer
