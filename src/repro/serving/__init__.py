"""Serving subsystem: paged KV-cache pool + continuous-batching engine.

- paged_cache: fixed-size page pool, host-side refcounted free-list
  allocator, per-request block tables (vLLM-style paging, TPU-shaped
  layout) and the prefix-sharing trie (PrefixCache) that maps identical
  page-aligned prompt prefixes onto the same physical pages with
  copy-on-write tail forks.
- scheduler: FIFO request queue with admission-on-free-pages, prefix-hit
  page mapping, and page reclamation when requests complete.
- engine: drives batched ragged admission prefill (one dispatch per
  segment boundary covering every admission's post-prefix suffix) +
  fixed-length decode scan segments, swapping finished requests for
  queued ones at segment boundaries.
"""

from repro.serving.paged_cache import (PageAllocator, PagedCacheConfig,
                                       PrefixCache, PrefixMatch,
                                       TRASH_PAGE, init_paged_cache,
                                       preferred_page_size)
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.serving.engine import PagedServingEngine

__all__ = [
    "PageAllocator", "PagedCacheConfig", "PrefixCache", "PrefixMatch",
    "TRASH_PAGE", "init_paged_cache", "preferred_page_size",
    "ContinuousBatchingScheduler", "Request", "PagedServingEngine",
]
