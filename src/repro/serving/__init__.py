"""Serving subsystem: paged KV-cache pool + continuous-batching engine.

- paged_cache: fixed-size page pool, host-side free-list allocator,
  per-request block tables (vLLM-style paging, TPU-shaped layout).
- scheduler: FIFO request queue with admission-on-free-pages and
  page reclamation when requests complete.
- engine: drives prefill-into-pages + fixed-length decode scan segments,
  swapping finished requests for queued ones at segment boundaries.
"""

from repro.serving.paged_cache import (PageAllocator, PagedCacheConfig,
                                       TRASH_PAGE, init_paged_cache)
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.serving.engine import PagedServingEngine

__all__ = [
    "PageAllocator", "PagedCacheConfig", "TRASH_PAGE", "init_paged_cache",
    "ContinuousBatchingScheduler", "Request", "PagedServingEngine",
]
