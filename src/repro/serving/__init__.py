"""Serving subsystem: paged KV-cache pool + continuous-batching engine
under a quota-aware preemptive resource manager.

- paged_cache: fixed-size page pool, host-side refcounted free-list
  allocator, per-request block tables (vLLM-style paging, TPU-shaped
  layout) and the prefix-sharing trie (PrefixCache) that maps identical
  page-aligned prompt prefixes onto the same physical pages with
  copy-on-write tail forks and an LRU pin budget that retains hot
  prefixes beyond their last request's lifetime.
- resources: the ResourceManager — growth-on-demand page sizing, host
  swap preemption snapshots, per-tenant page budgets with marginal
  charging for shared pages, deficit-round-robin scheduling credits, and
  victim selection (the policy layer everything else allocates through).
- scheduler: per-tenant request queues with DRR admission (restores
  before fresh admissions, no overtaking within a tenant), segment-
  boundary growth/preemption planning, and refcount-only page
  accounting.
- engine: drives batched ragged admission prefill + fixed-length decode
  scan segments; at segment boundaries it grows block tables, swaps
  preempted requests' pages to host memory, and restores them later in
  a single scatter dispatch (prefix-trie re-match first).
- faults: deterministic seed-driven fault injection (FaultPlan) over
  named sites threaded through the allocator, the swap path, and the
  engine's boundary dispatches — reproducible chaos for tests and CI.
- recovery: request-level self-healing — boundary checkpoints, fault
  quarantine with bounded retries and exponential segment backoff,
  swap-image checksums, an opt-in boundary invariant checker, load
  shedding with typed RequestFailed dead-letter records, and the
  EngineStalledError watchdog with its diagnostic snapshot.
- cluster: replicated serving — N EngineRun replicas of one compiled
  engine behind a prefix-affinity FrontDoor, a boundary-heartbeat
  health model (SUSPECT/DEAD with permanent fencing), cross-replica
  failover via verified host swap images, graceful drain/rejoin for
  rolling restarts, and typed ReplicaLost dead letters.
- plan: the ServingPlan — ONE frozen, JSON-round-trip artifact holding
  the whole deployment (pool geometry with tuned-tile provenance,
  scheduler cadence, tenant roster, cluster shape, durability knobs);
  engines, schedulers, resource managers and clusters all construct
  from it via ``from_plan``.
- journal: durable serving — a CRC-framed, segment-rotated write-ahead
  request journal (JournalWriter) with torn-tail-tolerant idempotent
  replay (replay_journal) and whole-process crash-restart recovery
  (RestartRecovery: plan JSON + journal → rebuilt engine/cluster that
  finishes every request bit-identical or typed-dead-letter), plus the
  process-level fault sites (wal_torn_write/wal_lost_fsync/
  process_crash → ProcessCrashed) that make crashes bisectable.
- traffic: seeded TrafficProfile workload generation + the replay
  scorer the SERVE design-flow task (tasks/serve.py) searches plans
  with.
- observe: the zero-dependency telemetry layer — a typed
  MetricsRegistry (counters always live behind the stats() views;
  histograms/gauges and the request-lifecycle Tracer gated by
  ObservabilityPolicy), Prometheus-text and JSONL exporters, and the
  render_summary roll-up bench rows embed.
"""

from repro.serving.paged_cache import (AllocatorError, PageAllocator,
                                       PagedCacheConfig, PrefixCache,
                                       PrefixMatch, TRASH_PAGE,
                                       init_paged_cache,
                                       preferred_page_size,
                                       preferred_segment_len)
from repro.serving.plan import (DurabilityPolicy, HealthPolicy,
                                ObservabilityPolicy, ServingPlan)
from repro.serving.observe import (MetricsRegistry, NULL_METRIC,
                                   Observability, Tracer,
                                   exponential_buckets, render_summary)
from repro.serving.traffic import TrafficProfile, make_replay_scorer, \
    replay
from repro.serving.faults import (ENGINE_SITES, FAULT_SITES,
                                  PROCESS_SITES, REPLICA_SITES,
                                  FaultPlan, FaultSpec, InjectedFault,
                                  ProcessCrashed)
from repro.serving.recovery import (EngineStalledError, RecoveryManager,
                                    RecoveryPolicy, RequestFailed,
                                    diagnostic_snapshot)
from repro.serving.resources import (DEFAULT_TENANT, ResourceManager,
                                     SwapState, TenantConfig)
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.serving.engine import EngineRun, PagedServingEngine
from repro.serving.cluster import (FrontDoor, Replica, ReplicaLost,
                                   ServingCluster)
from repro.serving.journal import (JOURNAL_VERSION, JournalError,
                                   JournalReplay, JournalWriter,
                                   ReplayedRequest, RestartRecovery,
                                   read_records, replay_journal)

__all__ = [
    "AllocatorError", "PageAllocator", "PagedCacheConfig", "PrefixCache",
    "PrefixMatch", "TRASH_PAGE", "init_paged_cache",
    "preferred_page_size", "preferred_segment_len",
    "DurabilityPolicy", "HealthPolicy", "ObservabilityPolicy",
    "ServingPlan",
    "MetricsRegistry", "NULL_METRIC", "Observability", "Tracer",
    "exponential_buckets", "render_summary",
    "TrafficProfile", "make_replay_scorer", "replay",
    "ENGINE_SITES", "FAULT_SITES", "PROCESS_SITES", "REPLICA_SITES",
    "FaultPlan", "FaultSpec", "InjectedFault", "ProcessCrashed",
    "EngineStalledError", "RecoveryManager", "RecoveryPolicy",
    "RequestFailed", "diagnostic_snapshot",
    "DEFAULT_TENANT", "ResourceManager", "SwapState", "TenantConfig",
    "ContinuousBatchingScheduler", "Request",
    "EngineRun", "PagedServingEngine",
    "FrontDoor", "Replica", "ReplicaLost", "ServingCluster",
    "JOURNAL_VERSION", "JournalError", "JournalReplay", "JournalWriter",
    "ReplayedRequest", "RestartRecovery", "read_records",
    "replay_journal",
]
