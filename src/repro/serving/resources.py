"""Quota-aware preemptive resource manager for the paged serving stack.

MetaML's thesis is that resource-constrained optimization decisions should
be automated policy, not hand tuning; this module is that policy layer for
the serving engine's budgeted resource — KV pages.  It replaces the PR-3
whole-lifetime reservation (``prompt + max_new + 1`` tokens locked at
admission) with three cooperating mechanisms:

- **Growth-on-demand paging** — an admission backs only the prompt plus
  one decode segment (:meth:`PagedCacheConfig.admission_tokens`); every
  later segment boundary tops a running request up to the next segment's
  coverage in :attr:`~PagedCacheConfig.growth_granule` multiples
  (:meth:`ResourceManager.growth_need` / :meth:`grow`).  The pool packs
  by what requests have *written*, not what they might write, so bursty
  admission waves co-reside where lifetime reservation would serialize.

- **Host-swap preemption** — when a growth allocation finds the pool dry
  (after the prefix cache's retention pins have been pressure-evicted),
  a victim is preempted: :meth:`preempt` snapshots its block-ordered page
  list + control state into a :class:`SwapState`, the engine
  ``jax.device_get``\\ s those pages to host memory, and the pages are
  released for the grower.  Re-admission is a *one-dispatch restore*:
  the prefix trie is consulted first (a still-resident prompt prefix is
  re-mapped by refcount, no data moves), and only the remaining blocks
  are scattered back from the host copy.  The anti-livelock rule: a
  restored request is ``protected`` — not a preemption candidate — until
  it has generated through one full decode segment.  Liveness follows:
  preemption only ever transfers pages to a *running* request whose
  remaining demand is finite, and a preempted request re-admits through
  the ordinary (never-preempting) admission path once pages free up.

- **Multi-tenant quotas + weighted scheduling** — every request carries a
  tenant; each tenant has a page budget and a scheduling weight
  (:class:`TenantConfig`).  Admission is deficit-round-robin across
  per-tenant FIFO queues (restores ahead of fresh admissions, no
  overtaking within a tenant): each round a tenant's deficit grows by
  ``weight x quantum`` pages and it admits heads while the deficit
  covers their *marginal* cost.  Quota accounting is marginal too — a
  prefix-shared page is charged to nobody but its allocator refcounts;
  a sharer pays only for its CoW fork and suffix pages — so sharing a
  system prompt never burns the sharer's budget.  A tenant at its budget
  can only preempt *its own* requests (quota pressure is private); pool
  pressure picks the victim from the most-over-share tenant
  (``charged / weight``), newest request first, so one tenant's burst is
  fed back to that tenant and cannot starve another's latency SLO.

The manager is pure host-side mechanism + policy: all device data
movement (page extraction, restore scatter) is executed by the engine at
segment boundaries, strictly before any dispatch that could overwrite a
released page.  ``scheduler.py`` drives the boundary protocol; this
module owns every page, charge, and victim decision.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import TYPE_CHECKING, Any, Iterable

from repro.serving.observe import Observability
from repro.serving.paged_cache import (PageAllocator, PagedCacheConfig,
                                       PrefixCache, PrefixMatch)

if TYPE_CHECKING:                        # import cycle: scheduler imports us
    from repro.serving.scheduler import Request

DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's share of the pool.

    ``page_budget`` caps the pages *charged* to the tenant at any instant
    (marginal accounting: prefix-shared pages are free, CoW forks and
    suffix/decode pages are not); None means the whole allocatable pool.
    ``weight`` scales the tenant's deficit-round-robin quantum — a
    weight-2 tenant admits twice the pages per round of a weight-1 one
    when both have queued work.
    """
    name: str
    weight: float = 1.0
    page_budget: int | None = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.page_budget is not None and self.page_budget < 1:
            raise ValueError(f"tenant {self.name!r}: page_budget must be "
                             f">= 1 (or None for the whole pool)")


@dataclasses.dataclass
class SwapState:
    """Host-side image of a preempted request, captured at the boundary.

    ``pages`` is the block-ordered physical page list that held tokens
    ``[0, n_tokens)`` at preemption time — snapshotted *before* release so
    the engine can ``device_get`` the K/V out of the pool before any
    later dispatch recycles those pages.  ``slot`` is the batch row the
    request vacated (the engine parks it on the scratch page).
    """
    pages: list[int]
    n_tokens: int                       # cache tokens resident at preempt
    slot: int
    host_k: Any = None                  # (L, len(pages), ps, KV, hd)
    host_v: Any = None
    # Integrity: CRC of (host_k, host_v) recorded by the engine at
    # device_get time; the recovery layer verifies it once before the
    # restore is planned and converts a mismatch (or a lost image) into
    # a full restart instead of scattering garbage K/V into the pool.
    checksum: int | None = None
    verified: bool = False


@dataclasses.dataclass
class _TenantState:
    cfg: TenantConfig
    rm: Any = None                      # owning ResourceManager backref
    pending: deque = dataclasses.field(default_factory=deque)
    preempted: deque = dataclasses.field(default_factory=deque)
    deficit: float = 0.0                # DRR credit, in pages
    charged: int = 0                    # pages currently charged

    # Lifetime counters (the bench/JSON schema) are thin views over the
    # metrics registry — the registry is the only bookkeeping, these
    # properties just filter it down to (replica, tenant).
    def _ctr(self, handle) -> int:
        return int(handle.value((self.rm._rep, self.cfg.name)))

    @property
    def admitted(self) -> int:
        return self._ctr(self.rm._c_admitted)

    @property
    def preempted_n(self) -> int:
        return self._ctr(self.rm._c_preempt)

    @property
    def restored(self) -> int:
        return self._ctr(self.rm._c_restores)

    @property
    def pages_swapped(self) -> int:     # pages device_get'd out on preempt
        return self._ctr(self.rm._c_swap_out)

    @property
    def dead_lettered(self) -> int:     # requests ended in RequestFailed
        return int(self.rm._c_dead.total(replica=self.rm._rep,
                                         tenant=self.cfg.name))

    @property
    def has_queued(self) -> bool:
        return bool(self.pending or self.preempted)

    def head(self) -> "Request | None":
        """Next admissible request: restores before fresh, FIFO within."""
        if self.preempted:
            return self.preempted[0]
        if self.pending:
            return self.pending[0]
        return None

    def pop_head(self) -> "Request":
        return (self.preempted.popleft() if self.preempted
                else self.pending.popleft())


@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """Everything an admission needs, decided before any state moves."""
    req: "Request"
    cost: int                           # fresh pages to charge
    n_shared: int                       # trie full pages re-mapped
    match: Any = None                   # PrefixMatch (fresh admissions)
    restore_blocks: tuple[int, int] = (0, 0)   # host blocks to scatter


class ResourceManager:
    """Owns the page allocator, tenant accounting, and preemption policy.

    Every page the serving stack touches moves through this object, and
    page *accounting* is exactly the allocator's refcounts: a request's
    ``pages`` list is its block table, ``charged`` is the fresh-page
    count billed to its tenant, and release/refund happen in one place
    (:meth:`release_request`) regardless of how the request ends —
    completion, preemption, or engine teardown.
    """

    @classmethod
    def from_plan(cls, plan, *, faults=None, obs=None) -> "ResourceManager":
        """Construct from a :class:`~repro.serving.plan.ServingPlan`:
        pool geometry, tenant roster, and the plan's effective sharing
        flag (prefix sharing requires the batched prefill path)."""
        return cls(plan.cache, plan.tenants or None,
                   sharing=plan.sharing, faults=faults, obs=obs)

    def __init__(self, pcfg: PagedCacheConfig,
                 tenants: Iterable[TenantConfig] | None = None,
                 *, sharing: bool | None = None, faults=None, obs=None):
        self.pcfg = pcfg
        self.allocator = PageAllocator(pcfg.n_pages, faults=faults)
        self.sharing = (pcfg.enable_prefix_sharing if sharing is None
                        else bool(sharing))
        self.prefix_cache = PrefixCache(
            self.allocator, pcfg.page_size,
            chunk_pages=pcfg.prefix_chunk_pages,
            retain_pages=pcfg.retain_pages) if self.sharing else None
        self._tenants: dict[str, _TenantState] = {}
        for t in tenants or ():
            self._tenants[t.name] = _TenantState(cfg=t, rm=self)
        # with an explicit tenant roster, unknown names are rejected at
        # submit — auto-registering them would hand a typo'd tenant a
        # default (whole-pool) budget and silently void the quotas
        self._closed_roster = bool(self._tenants)
        self._rr = 0                     # DRR rotation origin
        self._admit_seq = 0
        # All page-movement counters live in the metrics registry —
        # labeled (replica, tenant) so a cluster's replicas share one
        # store — and the legacy attributes/stats() keys read back
        # through it.  Counters are live even with telemetry disabled
        # (a fresh disabled Observability per manager keeps independent
        # engines isolated).
        self.obs = obs if obs is not None else Observability.disabled()
        self._rep = self.obs.replica
        lab = ("replica", "tenant")
        self._c_admitted = self.obs.counter(
            "serving_admitted_total",
            "fresh admissions committed", lab)
        self._c_preempt = self.obs.counter(
            "serving_preemptions_total",
            "requests host-swap preempted", lab)
        self._c_restores = self.obs.counter(
            "serving_restores_total",
            "preempted requests restored", lab)
        self._c_swap_out = self.obs.counter(
            "serving_pages_swapped_out_total",
            "pages device_get to host on preempt", lab)
        self._c_swap_in = self.obs.counter(
            "serving_pages_swapped_in_total",
            "host pages scattered back on restore", lab)
        self._c_grown = self.obs.counter(
            "serving_pages_grown_total",
            "pages added by growth-on-demand", lab)
        self._c_dead = self.obs.counter(
            "serving_dead_letters_total",
            "requests ended in typed RequestFailed",
            ("replica", "tenant", "site"))

    # ------------------------------------------------- registry thin views
    # The historical total attributes, as read-only filters over the
    # shared registry (per-tenant splits live on _TenantState).
    @property
    def preemptions(self) -> int:
        return int(self._c_preempt.total(replica=self._rep))

    @property
    def restores(self) -> int:
        return int(self._c_restores.total(replica=self._rep))

    @property
    def pages_swapped_out(self) -> int:
        return int(self._c_swap_out.total(replica=self._rep))

    @property
    def pages_swapped_in(self) -> int:
        return int(self._c_swap_in.total(replica=self._rep))

    @property
    def pages_grown(self) -> int:
        return int(self._c_grown.total(replica=self._rep))

    @property
    def dead_letters(self) -> int:
        return int(self._c_dead.total(replica=self._rep))

    def note_dead_letter(self, req: "Request", site: str) -> None:
        """Called by the recovery layer when a request dead-letters —
        the one increment behind every dead-letter count."""
        self._c_dead.inc(1.0, (self._rep, req.tenant, site))

    # ------------------------------------------------------------ tenants
    def state(self, name: str) -> _TenantState:
        """Tenant state.  Without an explicit roster, unknown tenants
        auto-register with defaults (unlimited budget, weight 1) so
        single-tenant callers never have to mention tenants at all; with
        one, an unknown name is an error — quotas only isolate if no
        request can route around them."""
        st = self._tenants.get(name)
        if st is None:
            if self._closed_roster:
                raise ValueError(
                    f"unknown tenant {name!r}: the configured roster is "
                    f"{sorted(self._tenants)}")
            st = _TenantState(cfg=TenantConfig(name=name), rm=self)
            self._tenants[name] = st
        return st

    def budget(self, name: str) -> int:
        b = self.state(name).cfg.page_budget
        return self.pcfg.allocatable_pages if b is None else b

    def headroom(self, name: str) -> int:
        return self.budget(name) - self.state(name).charged

    def validate(self, req: "Request") -> None:
        """Reject at submit what can never run: the whole-lifetime page
        demand must fit the pool *and* the tenant's budget (all those
        pages are simultaneously resident on the final decode step;
        prefix sharing may reduce the realized charge, but admission
        cannot rely on what may have been evicted by then)."""
        need = self.pcfg.validate_request(req.prompt_len,
                                          req.max_new_tokens)
        budget = self.budget(req.tenant)
        if need > budget:
            raise ValueError(
                f"request {req.rid!r}: lifetime demand of {need} pages "
                f"exceeds tenant {req.tenant!r} page_budget {budget}")

    def enqueue(self, req: "Request") -> None:
        self.state(req.tenant).pending.append(req)

    def queued(self) -> list["Request"]:
        """All queued requests, restores first, FIFO within each class."""
        out: list[Request] = []
        for st in self._tenants.values():
            out.extend(st.preempted)
        for st in self._tenants.values():
            out.extend(st.pending)
        return out

    @property
    def has_queued(self) -> bool:
        return any(st.has_queued for st in self._tenants.values())

    def drain_queued(self) -> list["Request"]:
        """Pop every queued request (both lanes, all tenants, restores
        first) and zero the DRR credit.  The cluster's drain/failover
        path migrates the returned requests to another replica; nothing
        queued holds pages, so no allocator state moves."""
        out: list[Request] = []
        for name in sorted(self._tenants):
            st = self._tenants[name]
            out.extend(st.preempted)
            st.preempted = deque()
        for name in sorted(self._tenants):
            st = self._tenants[name]
            out.extend(st.pending)
            st.pending = deque()
            st.deficit = 0.0
        return out

    # ------------------------------------------------------------- sizing
    def lifetime_pages(self, req: "Request") -> int:
        return self.pcfg.pages_for(
            self.pcfg.lifetime_tokens(req.prompt_len, req.max_new_tokens))

    def admission_pages(self, req: "Request") -> int:
        return self.pcfg.pages_for(
            self.pcfg.admission_tokens(req.prompt_len, req.max_new_tokens))

    def restore_target_pages(self, req: "Request") -> int:
        """A restore must cover its resident tokens plus one segment —
        the same coverage invariant a fresh admission gets, so a restored
        request never needs growth before its first (protected) segment."""
        return self.pcfg.pages_for(self.pcfg.coverage_tokens(
            req.swap.n_tokens, req.prompt_len, req.max_new_tokens))

    def growth_need(self, req: "Request") -> int:
        """Pages to add so the next segment's writes are backed
        (PagedCacheConfig.coverage_tokens from the current seq_len),
        rounded up to the growth granule, capped at the lifetime pages.
        0 when the current allocation already covers the segment — which
        also means a stalled request (inactive, seq_len frozen) is always
        safe: its parked write slot sits inside pages it already owns."""
        sl = req.prompt_len + len(req.tokens) - 1
        target = self.pcfg.coverage_tokens(sl, req.prompt_len,
                                           req.max_new_tokens)
        need = self.pcfg.pages_for(target) - len(req.pages)
        if need <= 0:
            return 0
        g = self.pcfg.growth_granule
        need = -(-need // g) * g
        return min(need, self.lifetime_pages(req) - len(req.pages))

    # -------------------------------------------------------- page moves
    def alloc_charged(self, req: "Request", n: int
                      ) -> tuple[list[int] | None, str | None]:
        """``n`` fresh pages charged to ``req``'s tenant, or
        ``(None, reason)`` with reason ``"quota"`` (tenant budget — only
        same-tenant victims can help) or ``"pool"`` (allocator dry —
        global pressure)."""
        if n == 0:
            return [], None
        st = self.state(req.tenant)
        if self.headroom(req.tenant) < n:
            return None, "quota"
        pages = self.allocator.alloc(n)
        if pages is None:
            return None, "pool"
        st.charged += n
        req.charged += n
        return pages, None

    def grow(self, req: "Request", n: int
             ) -> tuple[list[int] | None, str | None]:
        pages, reason = self.alloc_charged(req, n)
        if pages:
            req.pages.extend(pages)
            self._c_grown.inc(len(pages), (self._rep, req.tenant))
        return pages, reason

    def share(self, req: "Request", pages: list[int]) -> None:
        """Map already-resident pages into ``req`` (refcount bump, no
        charge — the marginal cost of a shared page is zero)."""
        if pages:
            self.allocator.share(pages)

    def release_pressure(self, n: int) -> int:
        """Pool-pressure callback: evict prefix-retention pins before any
        request is made to pay for them."""
        if self.prefix_cache is None or n <= 0:
            return 0
        return self.prefix_cache.release_pins(n)

    def release_request(self, req: "Request") -> None:
        """The single exit path for a request's pages: drop the CoW pin
        if the engine never ran its boundary, release one reference per
        block-table page, refund the tenant charge.  Everything else
        (free-list return, trie invalidation) follows from the
        allocator's refcounts."""
        if req.cow_src is not None:
            self.allocator.release([req.cow_src])
            req.cow_src = None
        if req.pages:
            self.allocator.release(req.pages)
        st = self.state(req.tenant)
        st.charged -= req.charged
        req.charged = 0
        req.pages = None

    # -------------------------------------------------------- preemption
    def pick_victim(self, running: Iterable["Request"],
                    exclude: "Request", tenant: str | None = None
                    ) -> "Request | None":
        """Preemption victim among ``running``: never the grower, never a
        ``protected`` (just-restored/admitted, pre-first-segment) request.
        Quota pressure (``tenant`` set) stays inside that tenant; pool
        pressure picks from the most-over-share tenant — highest
        ``charged / weight`` — so the burst pays for the burst.  Within a
        tenant the newest admission goes first (LIFO), preserving the
        FIFO completion order the queues promise."""
        cands = [r for r in running
                 if r is not exclude and not r.protected
                 and (tenant is None or r.tenant == tenant)]
        if not cands:
            return None
        if tenant is None:
            def key(r: "Request"):
                st = self.state(r.tenant)
                return (st.charged / st.cfg.weight, r.admit_seq)
        else:
            def key(r: "Request"):
                return (0, r.admit_seq)
        return max(cands, key=key)

    def preempt(self, req: "Request", requeue: bool = True) -> SwapState:
        """Snapshot ``req``'s device-resident state and release its
        pages.  The page *data* is untouched until some later dispatch
        reuses the pages — the engine must ``device_get`` the snapshot
        before issuing one (serving/engine.py sequences this).

        ``requeue=False`` leaves the request out of the tenant queues:
        the recovery layer uses this to quarantine a faulted request (it
        re-enters via :meth:`requeue` once its backoff expires)."""
        sl = req.prompt_len + len(req.tokens) - 1
        swap = SwapState(pages=list(req.pages[:self.pcfg.pages_for(sl)]),
                         n_tokens=sl, slot=req.slot)
        req.swap = swap
        st = self.state(req.tenant)
        self._c_preempt.inc(1.0, (self._rep, req.tenant))
        self._c_swap_out.inc(len(swap.pages), (self._rep, req.tenant))
        self.release_request(req)
        if requeue:
            st.preempted.append(req)
        return swap

    def requeue(self, req: "Request") -> None:
        """Return a quarantined request to its tenant's queues: with a
        (verified) host image through the preempted lane — a
        one-dispatch restore — and without one through the pending lane
        as a full restart."""
        st = self.state(req.tenant)
        if req.swap is not None:
            st.preempted.append(req)
        else:
            st.pending.append(req)

    # --------------------------------------------------------- admission
    def plan_admission(self, req: "Request") -> AdmissionPlan | str:
        """Decide an admission without moving state: the fresh-page cost
        (the DRR currency), the trie prefix re-map, and — for restores —
        which host blocks the engine must scatter back.  Returns a reason
        string (``"quota"``/``"pool"``) when resources block it."""
        restore = req.swap is not None
        if restore:
            need = self.restore_target_pages(req)
        else:
            need = self.admission_pages(req)
        match = None
        n_shared = 0
        if self.prefix_cache is not None:
            match = self.prefix_cache.lookup(req.prompt)
            n_shared = len(match.pages)
        if restore:
            # full-chunk prefix pages only: they are immutable and cover
            # tokens this request has definitely written (prompt ⊆
            # resident); the host image covers everything else, so a tail
            # CoW fork would copy data we already hold exactly.  Truncate
            # the match so the hit counters reflect what the restore
            # actually consumed.
            if match is not None:
                match = PrefixMatch(pages=match.pages,
                                    n_tokens=n_shared
                                    * self.pcfg.page_size)
            fresh = need - n_shared
            blocks = (n_shared, self.pcfg.pages_for(req.swap.n_tokens))
            plan = AdmissionPlan(req, cost=fresh, n_shared=n_shared,
                                 match=match, restore_blocks=blocks)
        else:
            fresh = need - n_shared
            plan = AdmissionPlan(req, cost=fresh, n_shared=n_shared,
                                 match=match)
        if fresh > self.headroom(req.tenant):
            return "quota"
        evictable = (self.prefix_cache.pinned_pages
                     if self.prefix_cache else 0)
        if fresh > self.allocator.n_free + evictable:
            # optimistic: pins count as free here, but are only evicted
            # at commit time — a plan the DRR deficit then rejects must
            # not strip retention as a planning side effect
            return "pool"
        return plan

    def commit_admission(self, plan: AdmissionPlan) -> bool:
        """Execute a planned admission: map shared pages, evict retention
        pins if the free list is short, allocate + bill fresh pages, arm
        the CoW fork, (re)index the trie.  Returns False — with no state
        changed beyond pin eviction — when the planner's optimistic pin
        accounting does not pan out (an evicted pin that other requests
        still reference frees nothing)."""
        req, match = plan.req, plan.match
        restore = req.swap is not None
        shared = list(match.pages[:plan.n_shared]) if match else []
        if shared:
            # share BEFORE evicting pins: a matched page may be alive
            # only through a retention pin, and the bumped refcount is
            # what keeps the eviction from freeing it mid-admission
            self.allocator.share(shared)
        short = plan.cost - self.allocator.n_free
        if short > 0:
            self.release_pressure(short)
        fresh, _reason = self.alloc_charged(req, plan.cost)
        if fresh is None:
            if shared:
                self.allocator.release(shared)
            return False
        req.pages = shared + fresh
        if restore:
            req.shared_tokens = 0        # restores never re-prefill
            req.shared_pages = 0
            self._c_restores.inc(1.0, (self._rep, req.tenant))
            self._c_swap_in.inc(
                max(0, plan.restore_blocks[1] - plan.restore_blocks[0]),
                (self._rep, req.tenant))
        else:
            req.shared_pages = plan.n_shared
            req.shared_tokens = match.n_tokens if match else 0
            if match and match.tail_src is not None:
                # pin the CoW source until the engine's boundary dispatch
                # has forked it (the owner could complete first).  The
                # fork target holds the LAST matched token — see
                # scheduler history for the exactly-full-tail case.
                self.allocator.share([match.tail_src])
                req.cow_src = match.tail_src
                req.cow_dst = req.pages[(match.n_tokens - 1)
                                        // self.pcfg.page_size]
            self._c_admitted.inc(1.0, (self._rep, req.tenant))
        if self.prefix_cache is not None:
            self.prefix_cache.record(match)
            self.prefix_cache.insert(req.prompt, req.prompt_len, req.pages)
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        req.protected = True             # anti-livelock: one segment grace
        return True

    # ---------------------------------------------------------------- DRR
    @property
    def quantum(self) -> float:
        """Pages of deficit credit per round for a weight-1 tenant."""
        return float(self.pcfg.growth_granule)

    def rotation(self) -> list[_TenantState]:
        """Tenant visit order for one boundary; the origin rotates so no
        tenant is permanently first when pages run out mid-round."""
        names = sorted(self._tenants)
        if not names:
            return []
        k = self._rr % len(names)
        self._rr += 1
        return [self._tenants[n] for n in names[k:] + names[:k]]

    def max_rounds(self) -> int:
        """Deficit accrual bound: the costliest admission is the whole
        pool, the slowest accrual is min-weight x quantum per round."""
        weights = [st.cfg.weight for st in self._tenants.values()
                   if st.has_queued]
        if not weights:
            return 1
        per_round = min(weights) * self.quantum
        return int(math.ceil(self.pcfg.allocatable_pages
                             / max(per_round, 1e-9))) + 2

    # -------------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        pc = self.prefix_cache
        return {
            "free_pages": self.allocator.n_free,
            "held_pages": self.allocator.n_held,
            "pages_allocated_total": self.allocator.pages_allocated_total,
            "pages_shared_total": self.allocator.pages_shared_total,
            "pages_grown": self.pages_grown,
            "preemptions": self.preemptions,
            "restores": self.restores,
            "pages_swapped_out": self.pages_swapped_out,
            "pages_swapped_in": self.pages_swapped_in,
            "free_low_water": self.allocator.free_low_water,
            "alloc_failures": self.allocator.alloc_failures,
            "dead_letters": self.dead_letters,
            "pinned_pages": pc.pinned_pages if pc else 0,
            "pin_evictions": pc.pin_evictions if pc else 0,
            "prefix_lookups": pc.lookups if pc else 0,
            "prefix_hits": pc.hits if pc else 0,
            "prefix_tokens_matched": pc.tokens_matched if pc else 0,
            "tenants": {
                name: {
                    "admitted": st.admitted,
                    "preempted": st.preempted_n,
                    "restored": st.restored,
                    "pages_swapped": st.pages_swapped,
                    "dead_lettered": st.dead_lettered,
                    "pages_charged": st.charged,
                    "page_budget": self.budget(name),
                    "queued": len(st.pending) + len(st.preempted),
                } for name, st in sorted(self._tenants.items())
            },
        }
