"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt [--mesh 1x1] [--inject-failures]

Full-size configs target the production mesh (run under the dry-run env);
--smoke runs the reduced config end-to-end on local devices — the same
loop, checkpointing, failure handling and data pipeline as at scale.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import ShardedBatcher, TokenSource
from repro.launch.mesh import make_host_mesh
from repro.models.api import build_model
from repro.optim.optimizers import adamw, cosine_schedule
from repro.parallel.sharding import ShardingRules
from repro.runtime.train_loop import FailureInjector, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", default="1x1",
                    help="dataxmodel, e.g. 2x4 (local devices)")
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps to fail at")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    dp, tp = (int(t) for t in args.mesh.split("x"))
    mesh = make_host_mesh(dp, tp)
    rules = ShardingRules.default(mesh)
    model = build_model(cfg, mesh=mesh)
    source = TokenSource(cfg.vocab_size, args.batch, args.seq_len)
    batcher = ShardedBatcher(source, rules)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    ckpt = CheckpointManager(ckpt_dir)
    injector = None
    if args.inject_failures:
        injector = FailureInjector(
            tuple(int(s) for s in args.inject_failures.split(",")))
    optimizer = adamw(cosine_schedule(args.lr, 10, args.steps))

    with mesh:
        report = train_loop(
            model, steps=args.steps, batcher=batcher, ckpt=ckpt,
            optimizer=optimizer, ckpt_every=args.ckpt_every,
            injector=injector,
            grad_compression=args.grad_compression,
            log=print)

    print(json.dumps({
        "arch": cfg.name, "steps_run": report.steps_run,
        "restarts": report.restarts,
        "straggler_events": report.straggler_events,
        "first_loss": report.losses[0] if report.losses else None,
        "final_loss": report.final_loss,
        "ckpt_dir": ckpt_dir,
        "devices": len(jax.devices()),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
