"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` supplies HLO_FLOPs / HLO_bytes; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Operand sizes are recovered from result shapes +
replica-group sizes (all-gather operand = result/group; reduce-scatter
operand = result*group; others operand≈result).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "link_bw": 50e9,          # bytes/s per ICI link
    "hbm_bytes": 16e9,        # v5e HBM capacity
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "f8e4m3": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[4,1024]{1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9_]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_INSTR_RE = re.compile(
    r"=\s*\((.*?)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n * b)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: dict[str, float]
    counts: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.operand_bytes.values())


def parse_collectives(hlo_text: str, world: int) -> CollectiveStats:
    operand_bytes = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        kind = None
        shapes: list[tuple[str, str]] = []
        m = _INSTR_RE.search(line)
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_INSTR_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if kind is None:
            continue
        if "-done(" in line:   # async pair: count only the -start
            continue
        result = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = max(1, _group_size(line, world))
        if kind == "all-gather":
            operand = result / g
        elif kind == "reduce-scatter":
            operand = result * g
        else:
            operand = result
        operand_bytes[kind] += operand
        counts[kind] += 1
    return CollectiveStats(operand_bytes, counts)


def roofline(compiled, mesh, model_flops: float | None = None,
             lowered_text: str | None = None,
             corrected: dict | None = None) -> dict[str, Any]:
    """Derive roofline terms from a jax.stages.Compiled.

    ``corrected``: scan-body-undercount correction from
    launch.dryrun.probe_layer_costs — when given, its extrapolated
    flops/bytes/collective-bytes replace the raw (body-counted-once)
    values; raw values are kept under ``raw_*`` keys.
    """
    chips = int(np.prod(mesh.devices.shape))
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collectives(text, chips)
    raw = {"raw_flops": flops, "raw_bytes": byts,
           "raw_collective_bytes": coll.total_bytes}
    if corrected is not None:
        flops = corrected["flops"]
        byts = corrected["bytes"]
        coll = CollectiveStats({"corrected": corrected["coll"]},
                               dict(coll.counts))

    # cost_analysis totals are per-device for SPMD modules
    compute_t = flops / HW["peak_flops"]
    memory_t = byts / HW["hbm_bw"]
    collective_t = coll.total_bytes / HW["link_bw"]

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                           + getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception:  # noqa: BLE001 — backend may not support it
        pass

    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)
    out = {
        **terms,
        "dominant": dominant,
        "bound_s": max(terms.values()),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": byts,
        "collective_bytes_per_chip": coll.total_bytes,
        "collective_counts": coll.counts,
        "collective_bytes_by_kind": coll.operand_bytes,
        "chips": chips,
        "memory": mem,
        "fits_hbm": (mem.get("peak_bytes", 0) <= HW["hbm_bytes"])
        if mem else None,
        **raw,
        "scan_corrected": corrected is not None,
    }
    if model_flops is not None:
        out["model_flops"] = model_flops
        hlo_total = flops * chips
        out["useful_flops_fraction"] = (model_flops / hlo_total
                                        if hlo_total else 0.0)
        out["mfu_bound"] = (model_flops / HW["peak_flops"] / chips
                            / max(out["bound_s"], 1e-30))
    return out


def format_roofline(name: str, r: dict[str, Any]) -> str:
    lines = [f"[{name}] chips={r['chips']}",
             f"  compute    {r['compute_s']*1e3:10.3f} ms"
             f"  ({r['hlo_flops_per_chip']/1e12:.2f} TFLOP/chip)",
             f"  memory     {r['memory_s']*1e3:10.3f} ms"
             f"  ({r['hlo_bytes_per_chip']/1e9:.2f} GB/chip)",
             f"  collective {r['collective_s']*1e3:10.3f} ms"
             f"  ({r['collective_bytes_per_chip']/1e9:.3f} GB/chip)",
             f"  dominant: {r['dominant']}  bound: "
             f"{r['bound_s']*1e3:.3f} ms"]
    if "useful_flops_fraction" in r:
        lines.append(f"  MODEL/HLO flops: {r['useful_flops_fraction']:.3f}"
                     f"   MFU-bound: {r.get('mfu_bound', 0):.3f}")
    if r.get("memory"):
        lines.append(f"  mem/chip: args {r['memory']['argument_bytes']/1e9:.2f} GB"
                     f" + temp {r['memory']['temp_bytes']/1e9:.2f} GB"
                     f"  fits16GB={r['fits_hbm']}")
    return "\n".join(lines)
