"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only launch/dryrun.py forces the 512-device placeholder platform.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the 2-pod / 512-chip configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
