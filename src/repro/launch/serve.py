"""Serving launcher: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Implements the inference half of the shape grid: one prefill step fills the
cache, then ``--gen`` single-token decode steps run against it (greedy).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.synthetic import lm_tokens
from repro.launch.mesh import make_host_mesh
from repro.models.api import build_model
from repro.parallel.sharding import ShardingRules


def generate(model, params, prompts, gen: int, cache_len: int):
    b, s = prompts.shape
    cache, _ = model.init_cache(b, cache_len)
    logits, cache = jax.jit(model.prefill)(params,
                                           {"tokens": prompts}, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.enc_dec:
        raise SystemExit("use examples/whisper_serve.py for enc-dec")
    dp, tp = (int(t) for t in args.mesh.split("x"))
    mesh = make_host_mesh(dp, tp)
    model = build_model(cfg, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    toks = lm_tokens(args.batch * args.prompt_len, cfg.vocab_size,
                     seed=1).reshape(args.batch, args.prompt_len)
    cache_len = args.prompt_len + args.gen + 1

    with mesh:
        t0 = time.time()
        out = generate(model, params, jnp.asarray(toks), args.gen,
                       cache_len)
        out.block_until_ready()
        dt = time.time() - t0

    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": int(out.shape[1]),
        "seconds": round(dt, 3),
        "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "sample": out[0, :8].tolist(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
