"""Serving launcher: batched prefill + fused-scan decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--kernels] [--no-scan]

Implements the inference half of the shape grid: one prefill step fills the
cache, then ``--gen`` greedy tokens are generated.  The decode loop is a
single ``jax.lax.scan`` inside one jit — greedy sampling carried in-graph —
so an N-token generation is one dispatch instead of N host round-trips
(``--no-scan`` keeps the legacy per-token Python loop for comparison;
``--kernels`` routes decode attention through the flash_decode Pallas
kernel).

Timing: compile/warmup runs outside the timed region, and prefill is timed
separately from decode — ``prefill_s`` and ``decode_tokens_per_s`` are
independent numbers (a wall clock that includes jit compilation made the
old ``tokens_per_s`` meaningless for small ``--gen``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.synthetic import lm_tokens
from repro.launch.mesh import make_host_mesh
from repro.models.api import build_model


def _greedy(logits) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ServeFns:
    """Jitted serving entry points, built once so recompilation never
    leaks into a timed region."""
    prefill: Callable[..., Any]
    decode_scan: Callable[..., Any]   # (params, cache, tok, steps) -> ...
    decode_one: Callable[..., Any]    # (params, cache, tok) -> ...


def make_serve_fns(model) -> ServeFns:
    def _decode_scan(params, cache, tok, steps: int):
        def step(carry, _):
            cache, tok = carry
            logits, cache = model.decode_step(params, cache, tok)
            nxt = _greedy(logits)
            return (cache, nxt), nxt

        (cache, _), toks = jax.lax.scan(step, (cache, tok), None,
                                        length=steps)
        # (steps, B, 1) -> (B, steps)
        return toks.transpose(1, 0, 2)[..., 0], cache

    return ServeFns(
        prefill=jax.jit(model.prefill),
        decode_scan=jax.jit(_decode_scan, static_argnums=(3,),
                            donate_argnums=(1,)),
        decode_one=jax.jit(model.decode_step, donate_argnums=(1,)),
    )


def generate(model, params, prompts, gen: int, cache_len: int, *,
             scan: bool = True, fns: ServeFns | None = None):
    """Greedy-generate ``gen`` tokens after prefilling ``prompts``.

    ``scan=True`` (default) runs all decode steps as one fused
    ``lax.scan`` dispatch; ``scan=False`` is the legacy per-token Python
    loop (kept as the dispatch-overhead baseline for bench_serve).
    """
    fns = fns or make_serve_fns(model)
    return timed_generate(model, params, prompts, gen, cache_len,
                          scan=scan, fns=fns)[0]


def timed_generate(model, params, prompts, gen: int, cache_len: int, *,
                   fns: ServeFns, scan: bool = True):
    """One timed prefill+decode pass.

    ``fns`` is required and must already be warm (run :func:`generate`
    once with the same shapes first) — building or compiling inside the
    timed region is exactly the bug this split exists to keep out.
    Returns (tokens, {"prefill_s", "decode_s"}) with the argmax of the
    prefill logits counted on the decode side of the split.
    """
    b, _ = prompts.shape
    cache, _ = model.init_cache(b, cache_len)
    jax.block_until_ready(cache)
    t0 = time.perf_counter()
    logits, cache = fns.prefill(params, {"tokens": prompts}, cache)
    jax.block_until_ready(logits)
    t1 = time.perf_counter()
    tok = _greedy(logits)
    out = [tok]
    if gen > 1:
        if scan:
            rest, _ = fns.decode_scan(params, cache, tok, gen - 1)
            out.append(rest)
        else:
            for _ in range(gen - 1):
                logits, cache = fns.decode_one(params, cache, tok)
                tok = _greedy(logits)
                out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    toks.block_until_ready()
    t2 = time.perf_counter()
    return toks, {"prefill_s": t1 - t0, "decode_s": t2 - t1}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--kernels", action="store_true",
                    help="decode attention via the flash_decode Pallas "
                         "kernel (interpret mode off-TPU)")
    ap.add_argument("--no-scan", action="store_true",
                    help="legacy per-token Python decode loop")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.enc_dec:
        raise SystemExit("use examples/whisper_serve.py for enc-dec")
    dp, tp = (int(t) for t in args.mesh.split("x"))
    mesh = make_host_mesh(dp, tp)
    interpret = jax.default_backend() != "tpu"
    model = build_model(cfg, mesh=mesh, use_kernels=args.kernels,
                        interpret=args.kernels and interpret)
    params = model.init(jax.random.PRNGKey(0))
    toks = lm_tokens(args.batch * args.prompt_len, cfg.vocab_size,
                     seed=1).reshape(args.batch, args.prompt_len)
    prompts = jnp.asarray(toks)
    cache_len = args.prompt_len + args.gen + 1
    scan = not args.no_scan

    with mesh:
        fns = make_serve_fns(model)
        # warmup: compile prefill + decode outside the timed region
        generate(model, params, prompts, args.gen, cache_len,
                 scan=scan, fns=fns).block_until_ready()
        out, t = timed_generate(model, params, prompts, args.gen,
                                cache_len, scan=scan, fns=fns)

    decode_tokens = args.batch * (out.shape[1] - 1)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": int(out.shape[1]),
        "scan": scan, "kernels": args.kernels,
        "prefill_s": round(t["prefill_s"], 4),
        "prefill_tokens_per_s": round(
            args.batch * args.prompt_len / max(t["prefill_s"], 1e-9), 1),
        "decode_s": round(t["decode_s"], 4),
        "decode_tokens_per_s": round(
            decode_tokens / max(t["decode_s"], 1e-9), 1),
        "sample": out[0, :8].tolist(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
