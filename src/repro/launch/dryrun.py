"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder-device flag before ANY other import (jax locks the
device count on first init) — see the first two lines.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the model + logical-axis shardings,
  3. jit-lowers the REAL train/prefill/decode step function with explicit
     in/out shardings,
  4. ``.compile()``s it — sharding mismatches, unsupported collectives and
     compile-time OOMs surface here,
  5. records memory_analysis / cost_analysis / collective-bytes roofline
     terms into benchmarks/results/dryrun_<...>.json for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k [--multi-pod] [--fsdp] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (env var must precede jax import)
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (SHAPES, ShapeSpec, active_params,
                                model_flops_per_token, shape_applicable,
                                total_params)
from repro.configs.registry import ALIASES, ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import format_roofline, roofline
from repro.models.api import build_model
from repro.optim.optimizers import adamw
from repro.parallel.sharding import ShardingRules
from repro.runtime.train_loop import (batch_shardings, cache_shardings,
                                      make_decode_step, make_prefill_step,
                                      make_train_step, state_shardings)

# archs whose params+moments need FSDP sharding over the dp axes
FSDP_ARCHS = {"deepseek-v2-236b", "qwen1.5-110b", "chameleon-34b"}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def _abstract_opt_state(params_abs):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)  # noqa: E731
    return {"step": jax.ShapeDtypeStruct((), jnp.int32),
            "m": jax.tree.map(f32, params_abs),
            "v": jax.tree.map(f32, params_abs)}


def lower_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool = False,
               fsdp: bool | None = None,
               rules_overrides: dict | None = None,
               cache_seq_axis: str | None = None,
               microbatches: int = 1,
               grad_compression: bool = False,
               remat: str | None = None,
               donate: bool = True,
               zero1: bool = False,
               policy_rules: list | None = None,
               moe_fsdp_mode: str = "gather",
               unroll_microbatches: bool = False,
               cfg_overrides: dict | None = None):
    """Returns (lowered, mesh, model, aux) — compile is the caller's call."""
    cfg = get_config(arch)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    use_fsdp = fsdp if fsdp is not None else (cfg.name in FSDP_ARCHS)
    overrides = dict(rules_overrides or {})
    if cache_seq_axis is not None:
        overrides["cache_seq"] = cache_seq_axis
    rules = ShardingRules.default(mesh, overrides=overrides)
    policy = None
    if policy_rules:
        from repro.models.api import DEFAULT_EXEMPT
        from repro.quant.policy import PrecisionPolicy
        policy = PrecisionPolicy(default="bf16", exempt=DEFAULT_EXEMPT,
                                 rules=[tuple(r) for r in policy_rules])
    model = build_model(cfg, mesh=mesh, fsdp_params=use_fsdp,
                        policy=policy, moe_fsdp_mode=moe_fsdp_mode)
    specs = model.input_specs(shape)

    with mesh:
        if shape.kind == "train":
            optimizer = adamw(3e-4)
            step_fn = make_train_step(
                model, optimizer, microbatches=microbatches,
                grad_compression=grad_compression,
                unroll_microbatches=unroll_microbatches)
            sshard = state_shardings(model, rules, "adamw", fsdp=use_fsdp,
                                     zero1=zero1)
            state_abs = {"params": model.abstract_params(),
                         "opt": _abstract_opt_state(
                             model.abstract_params())}
            if grad_compression:
                f32 = lambda s: jax.ShapeDtypeStruct(  # noqa: E731
                    s.shape, jnp.float32)
                state_abs["residuals"] = jax.tree.map(
                    f32, model.abstract_params())
                sshard = dict(sshard, residuals=sshard["params"])
            bshard = batch_shardings(model, rules, specs)
            fn = jax.jit(step_fn,
                         in_shardings=(sshard, bshard),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_abs, specs)
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model, shape.seq_len)
            pshard = state_shardings(model, rules, "sgd",
                                     fsdp=use_fsdp)["params"]
            bshard = batch_shardings(model, rules, specs)
            cshard = cache_shardings(model, rules, shape.global_batch,
                                     shape.seq_len)
            fn = jax.jit(step_fn, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
            lowered = fn.lower(model.abstract_params(), specs)
        else:  # decode
            step_fn = make_decode_step(model)
            pshard = state_shardings(model, rules, "sgd",
                                     fsdp=use_fsdp)["params"]
            cache_abs, _ = model.abstract_cache(shape.global_batch,
                                                shape.seq_len)
            cshard = cache_shardings(model, rules, shape.global_batch,
                                     shape.seq_len)
            tshard = rules.sharding_for(("batch", None), (b := shape.
                                                          global_batch, 1))
            fn = jax.jit(step_fn,
                         in_shardings=(pshard, cshard, tshard),
                         out_shardings=(None, cshard),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(model.abstract_params(), cache_abs,
                               jax.ShapeDtypeStruct((b, 1), jnp.int32))
    aux = {"fsdp": use_fsdp, "fallbacks": sorted(set(rules.fallbacks))}
    return lowered, mesh, model, aux


def _cell_model_flops(arch: str, shape: ShapeSpec) -> float:
    cfg = get_config(arch)
    per_tok = model_flops_per_token(cfg)
    if shape.kind == "train":
        return per_tok * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        # forward only
        return per_tok / 3.0 * shape.seq_len * shape.global_batch
    return per_tok / 3.0 * shape.global_batch  # one token per request


def _scan_unit(cfg) -> tuple[int, int]:
    """(layers-per-scan-unit, n-units) for the layer-cost extrapolation."""
    if cfg.family == "ssm":
        return 2, cfg.n_layers // 2
    if cfg.family == "hybrid":
        return cfg.hybrid_period, cfg.n_layers // cfg.hybrid_period
    return 1, cfg.n_layers


def probe_layer_costs(arch: str, shape: ShapeSpec, *,
                      multi_pod: bool = False, **kw) -> dict:
    """XLA cost analysis counts while-loop (scan) bodies ONCE, so the raw
    per-step FLOPs / bytes / collective-bytes of a scanned L-layer model
    are undercounted (validated empirically — EXPERIMENTS.md §Roofline).

    Fix: compile UNROLLED 1-unit and 2-unit variants of the model at full
    width on the same mesh and extrapolate linearly:

        cost(L) = cost(1) + (L - 1) * (cost(2) - cost(1))

    Returns corrected {flops, bytes, collective_bytes} per chip.
    """
    cfg = get_config(arch)
    unit, n_units = _scan_unit(cfg)
    out = {}
    base_kw = dict(kw)
    base_ov = base_kw.pop("cfg_overrides", None) or {}
    # the microbatch loop is ALSO a scan whose body cost_analysis counts
    # once — unroll it in probe compiles so microbatched costs are real
    base_kw["unroll_microbatches"] = True
    for k in (1, 2):
        ov = dict(base_ov)
        ov.update({"n_layers": unit * k, "scan_layers": False})
        if cfg.enc_dec:
            ov["n_enc_layers"] = k
        lowered, mesh, model, _ = lower_cell(
            arch, shape, multi_pod=multi_pod, cfg_overrides=ov, **base_kw)
        compiled = lowered.compile()
        from repro.launch.roofline import parse_collectives
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        chips = int(np.prod(mesh.devices.shape))
        coll = parse_collectives(compiled.as_text(), chips)
        out[k] = {"flops": float(ca.get("flops", 0.0)),
                  "bytes": float(ca.get("bytes accessed", 0.0)),
                  "coll": coll.total_bytes,
                  "coll_by_kind": dict(coll.operand_bytes)}
    corrected = {}
    for key in ("flops", "bytes", "coll"):
        per_unit = out[2][key] - out[1][key]
        corrected[key] = out[1][key] + (n_units - 1) * per_unit
    corrected["coll_by_kind"] = {
        kind: out[1]["coll_by_kind"][kind] + (n_units - 1)
        * (out[2]["coll_by_kind"][kind] - out[1]["coll_by_kind"][kind])
        for kind in out[1]["coll_by_kind"]}
    corrected["n_units"] = n_units
    corrected["probe_1"] = out[1]
    corrected["probe_2"] = out[2]
    return corrected


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, correct_scan: bool = True,
             **kw) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "params_total": total_params(cfg),
                 "params_active": active_params(cfg)}
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[{arch} x {shape_name}] SKIP: {why}")
        return rec
    t0 = time.time()
    try:
        lowered, mesh, model, aux = lower_cell(arch, shape,
                                               multi_pod=multi_pod, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        corrected = None
        if correct_scan:
            try:
                corrected = probe_layer_costs(arch, shape,
                                              multi_pod=multi_pod, **kw)
            except Exception as e:  # noqa: BLE001
                rec["probe_error"] = repr(e)
        r = roofline(compiled, mesh,
                     model_flops=_cell_model_flops(arch, shape),
                     corrected=corrected)
        rec.update(status="ok", roofline=r, lower_s=t_lower,
                   compile_s=t_compile, **aux)
        if verbose:
            print(format_roofline(f"{arch} x {shape_name} x {rec['mesh']}",
                                  r))
            print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"fallbacks={aux['fallbacks']}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=repr(e),
                   traceback=traceback.format_exc())
        if verbose:
            print(f"[{arch} x {shape_name}] ERROR: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]

    results = []
    for arch, shape in cells:
        results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                fsdp=args.fsdp))

    out = args.out
    if out is None:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = "multipod" if args.multi_pod else "singlepod"
        out = os.path.join(RESULTS_DIR, f"dryrun_{suffix}.json")
    existing = []
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)
    keyed = {(r["arch"], r["shape"], r["mesh"]): r for r in existing}
    for r in results:
        r.pop("traceback", None)
        keyed[(r["arch"], r["shape"], r["mesh"])] = r
    with open(out, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
