"""Batched serving example: prefill + KV-cache decode, with the
QUANTIZATION O-task's policy applied to the serving model (cross-stage:
the same policy object drives both accuracy evaluation and execution).

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2_7b]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402

from repro.configs.registry import get_config          # noqa: E402
from repro.data.synthetic import lm_tokens             # noqa: E402
from repro.models.api import build_model               # noqa: E402
from repro.quant.policy import PrecisionPolicy         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--int8", action="store_true",
                    help="serve under an int8 mlp policy")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    policy = PrecisionPolicy(default="bf16")
    if args.int8:
        policy = policy.with_rule("*mlp*", "int8")
    model = build_model(cfg, policy=policy)
    params = model.init(jax.random.PRNGKey(0))

    toks = lm_tokens(args.batch * args.prompt_len, cfg.vocab_size,
                     seed=7).reshape(args.batch, args.prompt_len)
    cache_len = args.prompt_len + args.gen + 1
    cache, _ = model.init_cache(args.batch, cache_len)

    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks)}, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    prefill_s = time.time() - t0

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} policy={'int8-mlp' if args.int8 else 'bf16'}")
    print(f"prefill {args.batch}x{args.prompt_len}: {prefill_s:.2f}s")
    print(f"decode  {args.gen - 1} steps: {decode_s:.2f}s "
          f"({args.batch * (args.gen - 1) / max(decode_s, 1e-9):.1f} "
          f"tok/s)")
    print("sample:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
