"""Quickstart: build and run a MetaML design flow in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's Fig. 2(a) pruning strategy on the Jet-DNN
benchmark, then prints the auto-pruning search trace (Fig. 3) and the
final resource reductions.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.metamodel import MetaModel            # noqa: E402
from repro.core.strategies import pruning_strategy    # noqa: E402


def main():
    # a design flow is data: tasks + connections, parameters in the CFG
    flow = pruning_strategy("jet_dnn", train_epochs=2)
    print(flow.to_dot())  # paper Fig. 2-style graph, renderable by dot

    meta = MetaModel({"ModelGen.train_samples": 2048,
                      "ModelGen.train_epochs": 4})
    meta = flow.execute(meta)

    print("\nAuto-pruning search (paper Fig. 3):")
    for i, p in enumerate(meta.trace("pruning.probe")):
        print(f"  s{i+1}: rate={p['rate']:.3f} acc={p['accuracy']:.4f} "
              f"{'ok' if p.get('feasible', True) else 'x'}")

    res = meta.get("pruning.result")
    print(f"\nselected rate: {res['pruning_rate']:.1%} "
          f"(accuracy {res['accuracy']:.4f}, "
          f"base {res['base_accuracy']:.4f})")
    print(f"effective-MACs (DSP analogue) reduced "
          f"{1 - res['macs_fraction']:.1%}")
    print("\nmodel space:")
    for art in meta.space_summary():
        print(f"  {art['name']} [{art['level']}]")


if __name__ == "__main__":
    main()
