"""Enc-dec (whisper-small family) serving: encode frames once, cache cross
K/V, decode autoregressively.

    PYTHONPATH=src python examples/whisper_serve.py [--gen 16]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402

from repro.configs.registry import get_config          # noqa: E402
from repro.models.api import build_model               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config("whisper_small", smoke=not args.full)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # conv-frontend stub: precomputed frame embeddings (spec contract)
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (args.batch, cfg.n_frames, cfg.d_model)
                               ) * 0.1
    bos = jnp.zeros((args.batch, 1), jnp.int32)

    t0 = time.time()
    cache, _ = model.init_cache(args.batch, args.gen + 2)
    logits, cache = model.prefill(
        params, {"frames": frames, "tokens": bos}, cache=cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    print(f"encode+prefill: {time.time()-t0:.2f}s "
          f"(cross K/V cached for {cfg.n_frames} frames)")

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    seq = jnp.concatenate(out, axis=1)
    print(f"decode {args.gen-1} steps: {time.time()-t0:.2f}s")
    print("tokens:", seq[0].tolist())


if __name__ == "__main__":
    main()
