"""Building a CUSTOM design flow — the paper's central claim is that new
strategies are a few lines: pick tasks, wire them (cycles allowed), tune
parameters through the shared CFG.

This example builds a flow the paper doesn't ship: an iterative
prune→quantize loop with a convergence condition on the weight-bits
resource (keep optimizing while the last pass improved it by >10%),
followed by a TUNE stage that autotunes the Pallas tile configs for the
shapes the optimized model executes (docs/autotune.md); then compares
O-task orders.

    PYTHONPATH=src python examples/custom_flow.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.flow import DesignFlow                 # noqa: E402
from repro.core.metamodel import MetaModel             # noqa: E402
from repro.core.strategies import combined_strategy    # noqa: E402
from repro.tasks.model_gen import ModelGen             # noqa: E402
from repro.tasks.pruning import Pruning                # noqa: E402
from repro.tasks.quantization import Quantization      # noqa: E402
from repro.tasks.tune import Tune                      # noqa: E402

CFG = {"ModelGen.train_samples": 2048, "ModelGen.train_epochs": 4,
       "Pruning.train_epochs": 1, "Pruning.pruning_rate_thresh": 0.1}


def improving(meta: MetaModel, outputs) -> bool:
    """Back-edge condition: loop while weight-bits dropped >10%."""
    hist = meta.get("bits_history", [])
    bits = meta.model(outputs[0]).metrics.get("weight_bits", 0)
    hist.append(bits)
    meta.set("bits_history", hist)
    if len(hist) < 2 or len(hist) > 4:      # bound the loop
        keep_going = len(hist) < 2
    else:
        keep_going = hist[-1] < 0.9 * hist[-2]
    meta.set("pq_improving", keep_going)    # read by the TUNE edge
    return keep_going


def converged(meta: MetaModel, outputs) -> bool:
    """TUNE-edge condition: fire once the P<->Q loop stops improving.

    Reads the decision ``improving`` recorded (the back edge is created
    first, so it is evaluated first per dispatch) — re-running the
    threshold logic here would duplicate it and double-append the history.
    """
    return not meta.get("pq_improving", True)


def build_iterative_flow() -> DesignFlow:
    flow = DesignFlow("iterative-PQT")
    gen = flow.add(ModelGen(model="jet_dnn"))
    prune = flow.add(Pruning(train_epochs=1, pruning_rate_thresh=0.1))
    quant = flow.add(Quantization(tolerate_acc_loss=0.02))
    # TUNE last: it sees the pruned/quantized artifact, so it tunes the
    # Pallas tile configs for the kernels that model actually executes.
    tune = flow.add(Tune(max_trials=4, iters=1, max_problems=2))
    flow.connect(gen, prune)
    flow.connect(prune, quant)
    flow.connect(quant, prune, condition=improving)   # the cycle
    flow.connect(quant, tune, condition=converged)
    return flow


def main():
    flow = build_iterative_flow()
    print(flow.to_dot())
    meta = flow.execute(MetaModel(dict(CFG)))
    final = meta.latest("dnn")
    print(f"\niterative P<->Q: acc={final.metrics['accuracy']:.4f} "
          f"bits={final.metrics['weight_bits']:.0f} "
          f"(history {meta.get('bits_history')})")
    tuned = meta.get("tune.result", {})
    print(f"TUNE: {tuned.get('search_steps', 0)} tile probes -> "
          f"{len(tuned.get('configs', {}))} tuned kernel configs")

    # order sensitivity, one-character edits (paper Fig. 5)
    for order in ("PQ", "QP"):
        m = combined_strategy("jet_dnn", order).execute(
            MetaModel(dict(CFG)))
        art = m.latest("dnn")
        print(f"order {order}: acc={art.metrics['accuracy']:.4f} "
              f"bits={art.metrics['weight_bits']:.0f}")


if __name__ == "__main__":
    main()
