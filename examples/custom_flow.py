"""Building a CUSTOM design flow — the paper's central claim is that new
strategies are a few lines: pick tasks, wire them (cycles allowed), tune
parameters through the shared CFG.

This example builds a flow the paper doesn't ship: an iterative
prune→quantize loop with a convergence condition on the weight-bits
resource (keep optimizing while the last pass improved it by >10%), then
compares O-task orders.

    PYTHONPATH=src python examples/custom_flow.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.flow import DesignFlow                 # noqa: E402
from repro.core.metamodel import MetaModel             # noqa: E402
from repro.core.strategies import combined_strategy    # noqa: E402
from repro.tasks.model_gen import ModelGen             # noqa: E402
from repro.tasks.pruning import Pruning                # noqa: E402
from repro.tasks.quantization import Quantization      # noqa: E402

CFG = {"ModelGen.train_samples": 2048, "ModelGen.train_epochs": 4,
       "Pruning.train_epochs": 1, "Pruning.pruning_rate_thresh": 0.1}


def improving(meta: MetaModel, outputs) -> bool:
    """Back-edge condition: loop while weight-bits dropped >10%."""
    hist = meta.get("bits_history", [])
    bits = meta.model(outputs[0]).metrics.get("weight_bits", 0)
    hist.append(bits)
    meta.set("bits_history", hist)
    if len(hist) < 2 or len(hist) > 4:      # bound the loop
        return len(hist) < 2
    return hist[-1] < 0.9 * hist[-2]


def build_iterative_flow() -> DesignFlow:
    flow = DesignFlow("iterative-PQ")
    gen = flow.add(ModelGen(model="jet_dnn"))
    prune = flow.add(Pruning(train_epochs=1, pruning_rate_thresh=0.1))
    quant = flow.add(Quantization(tolerate_acc_loss=0.02))
    flow.connect(gen, prune)
    flow.connect(prune, quant)
    flow.connect(quant, prune, condition=improving)   # the cycle
    return flow


def main():
    flow = build_iterative_flow()
    print(flow.to_dot())
    meta = flow.execute(MetaModel(dict(CFG)))
    final = meta.latest("dnn")
    print(f"\niterative P<->Q: acc={final.metrics['accuracy']:.4f} "
          f"bits={final.metrics['weight_bits']:.0f} "
          f"(history {meta.get('bits_history')})")

    # order sensitivity, one-character edits (paper Fig. 5)
    for order in ("PQ", "QP"):
        m = combined_strategy("jet_dnn", order).execute(
            MetaModel(dict(CFG)))
        art = m.latest("dnn")
        print(f"order {order}: acc={art.metrics['accuracy']:.4f} "
              f"bits={art.metrics['weight_bits']:.0f}")


if __name__ == "__main__":
    main()
