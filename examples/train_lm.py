"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack — sharded data pipeline, AdamW + cosine schedule,
async checkpointing, fault injection + automatic restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]

Default uses a ~100M-param xLSTM-125m-family config scaled for CPU wall
time; --full uses the real xlstm-125m config (slower on CPU, same code
path as the TPU launch).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                             # noqa: E402

from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.configs.registry import get_config           # noqa: E402
from repro.data.pipeline import ShardedBatcher, TokenSource  # noqa: E402
from repro.models.api import build_model                # noqa: E402
from repro.optim.optimizers import adamw, cosine_schedule  # noqa: E402
from repro.runtime.train_loop import (FailureInjector,  # noqa: E402
                                      train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="real xlstm-125m config (~125M params)")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_config("xlstm_125m", smoke=not args.full)
    if not args.full:
        # ~100M-param training exercise at CPU-tractable width
        cfg = cfg.replace(n_layers=4, d_model=256, n_heads=4,
                          vocab_size=8192, mlstm_chunk=64)
    model = build_model(cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(
        model.abstract_params()))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps}")

    source = TokenSource(cfg.vocab_size, args.batch, args.seq_len,
                         n_tokens=1 << 22)
    batcher = ShardedBatcher(source, rules=None)
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="train_lm_"), keep=2)
    injector = None
    if args.inject_failure_at >= 0:
        injector = FailureInjector((args.inject_failure_at,))

    report = train_loop(
        model, steps=args.steps, batcher=batcher, ckpt=ckpt,
        optimizer=adamw(cosine_schedule(3e-4, 20, args.steps),
                        weight_decay=0.1),
        ckpt_every=50, injector=injector, log=print)

    print(f"\nsteps={report.steps_run} restarts={report.restarts}")
    print(f"loss: {report.losses[0]:.3f} -> {report.final_loss:.3f}")
    k = max(1, len(report.losses) // 10)
    for i in range(0, len(report.losses), k):
        print(f"  step {i:4d}: {report.losses[i]:.3f}")


if __name__ == "__main__":
    main()
