"""SSM numerics: chunked parallel forms vs recurrent references."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models import ssm as S
from repro.models.common import Ctx

KEY = jax.random.PRNGKey(0)


class TestMLSTM:
    @pytest.mark.parametrize("seq,chunk", [(32, 8), (64, 16), (48, 16),
                                           (17, 8)])
    def test_chunked_vs_recurrent(self, seq, chunk):
        cfg = get_config("xlstm_125m", smoke=True).replace(
            mlstm_chunk=chunk)
        b, h, dh = 2, 3, 16
        ks = jax.random.split(KEY, 5)
        q = jax.random.normal(ks[0], (b, seq, h, dh))
        k = jax.random.normal(ks[1], (b, seq, h, dh)) / 4
        v = jax.random.normal(ks[2], (b, seq, h, dh))
        logi = jax.random.normal(ks[3], (b, seq, h))
        logf = jax.nn.log_sigmoid(
            jax.random.normal(ks[4], (b, seq, h)) + 2.0)
        y_chunk, _ = S._mlstm_chunked(cfg, q, k, v, logi, logf)
        y_ref = S.mlstm_recurrent_reference(cfg, q, k, v, logi, logf)
        assert float(jnp.max(jnp.abs(y_chunk - y_ref))) < 1e-4

    def test_prefill_decode_handoff(self):
        cfg = get_config("xlstm_125m", smoke=True)
        p, _ = S.init_mlstm(KEY, cfg)
        b, s, d = 2, 33, cfg.d_model
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.3
        y_full, _ = S.mlstm_apply(Ctx(), cfg, p, x)
        st, _ = S.init_mlstm_state(cfg, b)
        _, st = S.mlstm_apply(Ctx(), cfg, p, x[:, :s - 1], st)
        y_dec, _ = S.mlstm_apply(Ctx(decode=True), cfg, p, x[:, s - 1:],
                                 st)
        assert float(jnp.max(jnp.abs(y_dec - y_full[:, -1:]))) < 1e-4


class TestMamba2:
    @pytest.mark.parametrize("seq", [32, 48, 63])
    def test_chunked_vs_stepwise(self, seq):
        cfg = get_config("zamba2_2p7b", smoke=True)
        p, _ = S.init_mamba2(KEY, cfg)
        b, d = 2, cfg.d_model
        x = jax.random.normal(jax.random.PRNGKey(1), (b, seq, d)) * 0.5
        y_par, _ = S.mamba2_apply(Ctx(), cfg, p, x)
        st, _ = S.init_mamba2_state(cfg, b)
        ys = []
        ctx_d = Ctx(decode=True)
        for t in range(seq):
            yt, st = S.mamba2_apply(ctx_d, cfg, p, x[:, t:t + 1], st)
            ys.append(yt)
        y_rec = jnp.concatenate(ys, axis=1)
        assert float(jnp.max(jnp.abs(y_par - y_rec))) < 1e-4

    def test_prefill_state_handoff(self):
        cfg = get_config("zamba2_2p7b", smoke=True)
        p, _ = S.init_mamba2(KEY, cfg)
        b, s, d = 2, 40, cfg.d_model
        x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d)) * 0.5
        y_full, _ = S.mamba2_apply(Ctx(), cfg, p, x)
        st, _ = S.init_mamba2_state(cfg, b)
        _, st = S.mamba2_apply(Ctx(), cfg, p, x[:, :s - 1], st)
        y_dec, _ = S.mamba2_apply(Ctx(decode=True), cfg, p, x[:, s - 1:],
                                  st)
        assert float(jnp.max(jnp.abs(y_dec - y_full[:, -1:]))) < 1e-4

    def test_decay_monotonic_state_bounded(self):
        """SSD state stays bounded for bounded inputs (stability)."""
        cfg = get_config("zamba2_2p7b", smoke=True)
        p, _ = S.init_mamba2(KEY, cfg)
        b, d = 1, cfg.d_model
        st, _ = S.init_mamba2_state(cfg, b)
        x = jnp.ones((b, 1, d)) * 0.1
        ctx = Ctx(decode=True)
        for _ in range(64):
            _, st = S.mamba2_apply(ctx, cfg, p, x, st)
        assert bool(jnp.all(jnp.isfinite(st["ssd"])))


class TestSLSTM:
    def test_prefill_decode_handoff(self):
        cfg = get_config("xlstm_125m", smoke=True)
        p, _ = S.init_slstm(KEY, cfg)
        b, s, d = 2, 20, cfg.d_model
        x = jax.random.normal(jax.random.PRNGKey(3), (b, s, d)) * 0.3
        y_full, _ = S.slstm_apply(Ctx(), cfg, p, x)
        st, _ = S.init_slstm_state(cfg, b)
        _, st = S.slstm_apply(Ctx(), cfg, p, x[:, :s - 1], st)
        y_dec, _ = S.slstm_apply(Ctx(decode=True), cfg, p, x[:, s - 1:],
                                 st)
        assert float(jnp.max(jnp.abs(y_dec - y_full[:, -1:]))) < 1e-4

    def test_gating_saturation_stable(self):
        """Large gate pre-activations must not produce NaN (stabilized
        exponential gating)."""
        cfg = get_config("xlstm_125m", smoke=True)
        p, _ = S.init_slstm(KEY, cfg)
        x = jnp.ones((1, 8, cfg.d_model)) * 50.0
        y, _ = S.slstm_apply(Ctx(), cfg, p, x)
        assert bool(jnp.all(jnp.isfinite(y)))
