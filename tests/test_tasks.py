"""O-task / λ-task integration: the paper's strategies end-to-end (small)."""

import pytest

from repro.core.metamodel import MetaModel
from repro.core.strategies import (combined_strategy, pruning_strategy,
                                   quantization_strategy, scaling_strategy)

FAST = dict(train_epochs=1, train_samples=1024)


@pytest.fixture(scope="module")
def pruned_meta():
    flow = pruning_strategy("jet_dnn", train_epochs=1,
                            pruning_rate_thresh=0.1)
    meta = MetaModel({"ModelGen.train_samples": 1024,
                      "ModelGen.train_epochs": 2})
    return flow.execute(meta)


class TestPruningStrategy:
    def test_finds_nonzero_rate_within_tolerance(self, pruned_meta):
        res = pruned_meta.get("pruning.result")
        assert res["pruning_rate"] > 0.2
        assert res["base_accuracy"] - res["accuracy"] <= 0.02 + 1e-9

    def test_resource_proxy_decreases(self, pruned_meta):
        res = pruned_meta.get("pruning.result")
        assert res["macs_fraction"] < 0.8  # DSP-analogue reduction

    def test_step_count_bounded(self, pruned_meta):
        # 1 + log2(1/beta) formula: beta=0.1 -> ~4.3 bisections + 2 probes
        res = pruned_meta.get("pruning.result")
        assert res["search_steps"] <= 8

    def test_model_space_lineage(self, pruned_meta):
        art = pruned_meta.latest("dnn")
        lineage = pruned_meta.lineage(art.name)
        assert len(lineage) == 2  # pruned -> generated

    def test_probe_trace_recorded(self, pruned_meta):
        probes = pruned_meta.trace("pruning.probe")
        assert len(probes) >= 3
        assert all("accuracy" in p for p in probes)


class TestQuantizationStrategy:
    def test_weight_bits_reduced_at_tolerance(self):
        meta = MetaModel({"ModelGen.train_samples": 1024,
                          "ModelGen.train_epochs": 2})
        quantization_strategy("jet_dnn",
                              tolerate_acc_loss=0.02).execute(meta)
        res = meta.get("quantization.result")
        assert res["base_accuracy"] - res["accuracy"] < 0.02 + 1e-9
        # fp32 -> int8 everywhere would be 4x; require at least 2x
        gen = next(iter(meta.models("dnn"))).metrics
        assert res["weight_bits"] <= gen["weight_bits"] / 2


class TestScalingStrategy:
    def test_scaling_shrinks_when_tolerant(self):
        # generous tolerance: the paper's claim under test is the search
        # mechanics (walk the ladder, keep the last feasible width), not a
        # specific accuracy on synthetic data
        meta = MetaModel({"ModelGen.train_samples": 1024,
                          "ModelGen.train_epochs": 2})
        scaling_strategy("jet_dnn", tolerate_acc_loss=0.2,
                         max_trials_num=2,
                         train_epochs=3).execute(meta)
        res = meta.get("scaling.result")
        assert res["scale"] < 1.0
        assert res["base_accuracy"] - res["accuracy"] <= 0.2 + 1e-9
        assert len(meta.trace("scaling.probe")) >= 1


class TestCombinedStrategy:
    def test_order_is_programmable(self):
        f1 = combined_strategy("jet_dnn", "SP")
        f2 = combined_strategy("jet_dnn", "PS")
        names1 = [t.name for t in f1.tasks]
        names2 = [t.name for t in f2.tasks]
        assert names1 == ["ModelGen", "Scaling", "Pruning"]
        assert names2 == ["ModelGen", "Pruning", "Scaling"]

    def test_pq_combined_runs(self):
        meta = MetaModel({"ModelGen.train_samples": 768,
                          "ModelGen.train_epochs": 2,
                          "Pruning.train_epochs": 1,
                          "Pruning.pruning_rate_thresh": 0.2})
        combined_strategy("jet_dnn", "PQ").execute(meta)
        art = meta.latest("dnn")
        assert art.name.startswith("jet_dnn+P+Q".split("+")[0])
        # both O-tasks left their marks
        assert meta.get("pruning.result") is not None
        assert meta.get("quantization.result") is not None
        # combined resources beat single-task pruning alone
        q = meta.get("quantization.result")
        assert q["weight_bits"] < meta.get("pruning.result")["weight_bits"]


class TestLMOtasks:
    def test_pruning_on_lm_arch(self):
        """O-tasks apply to the assigned LM archs too (DESIGN.md §4)."""
        from repro.core.flow import DesignFlow
        from repro.tasks.model_gen import ModelGen
        from repro.tasks.pruning import Pruning
        flow = DesignFlow("lm-prune")
        flow.chain(ModelGen(model="qwen2_7b", smoke=True, train_en=False),
                   Pruning(train_epochs=1, pruning_rate_thresh=0.25,
                           tolerate_acc_loss=0.5))
        meta = flow.execute()
        res = meta.get("pruning.result")
        assert res is not None and res["search_steps"] >= 2
