"""Durable serving: write-ahead journal framing/rotation/replay, the
wal fault sites, DurabilityPolicy plan wiring, and end-to-end
crash-restart recovery (single engine and cluster) that must finish
bit-identical to an uninterrupted run."""

import dataclasses
import json
import os
import struct

import numpy as np
import jax
import pytest

from repro.serving import (DurabilityPolicy, FaultPlan, JOURNAL_VERSION,
                           JournalError, JournalWriter, PagedCacheConfig,
                           PagedServingEngine, ProcessCrashed,
                           ReplicaLost, Request, RequestFailed,
                           RestartRecovery, ServingCluster, ServingPlan,
                           read_records, replay_journal)
from repro.serving.journal import (_load_image, _save_image)


def _seg_files(d):
    return sorted(f for f in os.listdir(d) if f.startswith("wal-"))


def _mk_req(rid, prompt_len=4, gen=5, tokens=()):
    req = Request(rid=rid,
                  prompt=np.arange(prompt_len, dtype=np.int32),
                  max_new_tokens=gen)
    req.tokens = list(tokens)
    return req


# ------------------------------------------------------------- framing
class TestFraming:
    def test_lifecycle_round_trip(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        req = _mk_req(1)
        w.submit(req)
        req.tokens = [7, 8]
        w.admit(req, restore=False)
        w.checkpoint(1, [req])
        req.tokens = [7, 8, 9, 10, 11]
        w.complete(req)
        w.close()
        rp = replay_journal(d)
        assert not rp.truncated
        r = rp.requests[1]
        assert r.status == "completed"
        assert r.tokens == [7, 8, 9, 10, 11]
        assert r.prompt == [0, 1, 2, 3]
        assert r.max_new_tokens == 5

    def test_segment_rotation(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d, segment_bytes=256)
        for i in range(30):
            w.submit(_mk_req(i, prompt_len=8))
        w.close()
        assert len(_seg_files(d)) > 1
        rp = replay_journal(d)
        assert not rp.truncated
        assert sorted(rp.requests) == list(range(30))
        # records never split across segments: the whole dir parses clean
        recs, torn = read_records(d)
        assert len(recs) == 30 and not torn

    def test_torn_tail_dropped_not_fatal(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        for i in range(3):
            w.submit(_mk_req(i))
        w.close()
        seg = os.path.join(d, _seg_files(d)[-1])
        with open(seg, "ab") as f:
            f.write(b"\x40\x00\x00\x00\xde\xad\xbe\xefhalf a rec")
        rp = replay_journal(d)
        assert rp.truncated
        assert sorted(rp.requests) == [0, 1, 2]

    def test_crc_corrupt_tail_dropped(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        for i in range(3):
            w.submit(_mk_req(i))
        w.close()
        seg = os.path.join(d, _seg_files(d)[-1])
        data = bytearray(open(seg, "rb").read())
        data[-2] ^= 0xFF                # flip a byte in the last payload
        open(seg, "wb").write(bytes(data))
        rp = replay_journal(d)
        assert rp.truncated
        assert sorted(rp.requests) == [0, 1]

    def test_mid_journal_corruption_is_conservative_prefix(self, tmp_path):
        """Corruption in an EARLIER segment drops everything after it —
        resyncing past a bad frame could interleave crash states."""
        d = str(tmp_path)
        w = JournalWriter(d, segment_bytes=256)
        for i in range(30):
            w.submit(_mk_req(i, prompt_len=8))
        w.close()
        segs = _seg_files(d)
        assert len(segs) >= 3
        first = os.path.join(d, segs[0])
        data = bytearray(open(first, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(first, "wb").write(bytes(data))
        rp = replay_journal(d)
        assert rp.truncated
        # a strict prefix of request 0..k survives, nothing after
        rids = sorted(rp.requests)
        assert rids == list(range(len(rids)))
        assert len(rids) < 30

    def test_reopen_repairs_torn_tail_and_appends(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        w.submit(_mk_req(0))
        w.close()
        seg = os.path.join(d, _seg_files(d)[-1])
        with open(seg, "ab") as f:
            f.write(b"\x10\x00\x00\x00torn")
        w2 = JournalWriter(d)
        w2.submit(_mk_req(1))
        w2.close()
        rp = replay_journal(d)
        assert not rp.truncated         # the tail was truncated away
        assert sorted(rp.requests) == [0, 1]

    def test_unknown_type_and_future_version_skipped(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        w.submit(_mk_req(0))
        w.append("FROM_THE_FUTURE", {"rid": 99}, flush=True)
        w.close()
        frame = json.dumps({"v": JOURNAL_VERSION + 1, "t": "SUBMIT",
                            "rid": 98}).encode()
        import zlib
        with open(os.path.join(d, _seg_files(d)[-1]), "ab") as f:
            f.write(struct.pack("<II", len(frame), zlib.crc32(frame))
                    + frame)
        rp = replay_journal(d)
        assert sorted(rp.requests) == [0]
        assert rp.n_skipped == 2

    def test_closed_writer_raises(self, tmp_path):
        w = JournalWriter(str(tmp_path))
        w.close()
        with pytest.raises(JournalError):
            w.submit(_mk_req(0))

    def test_crash_drops_unflushed_buffer(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d, fsync_boundaries=100)
        w.submit(_mk_req(0))            # terminal: flushed immediately
        w.checkpoint(1, [_mk_req(0, tokens=[1])])   # buffered
        w.crash()
        rp = replay_journal(d)
        assert rp.requests[0].status == "submitted"
        assert rp.requests[0].n_tokens == 0


# ----------------------------------------------------------- wal faults
class TestWalFaults:
    def test_wal_torn_write(self, tmp_path):
        """The fired record lands truncated, everything before it whole,
        nothing after it at all — and replay degrades to the prefix."""
        d = str(tmp_path)
        fp = FaultPlan.at(wal_torn_write=2)
        w = JournalWriter(d, faults=fp)
        for i in range(5):
            w.submit(_mk_req(i))
        w.close()
        assert fp.fires["wal_torn_write"] == 1
        rp = replay_journal(d)
        assert rp.truncated
        assert sorted(rp.requests) == [0, 1]

    def test_wal_lost_fsync_is_a_hole_not_a_prefix(self, tmp_path):
        """A dropped fsync batch loses its records while later batches
        still land: framing stays intact, the records are just gone."""
        d = str(tmp_path)
        fp = FaultPlan.at(wal_lost_fsync=1)
        w = JournalWriter(d, faults=fp)
        for i in range(4):
            w.submit(_mk_req(i))        # each submit is its own flush
        w.close()
        assert fp.fires["wal_lost_fsync"] == 1
        rp = replay_journal(d)
        assert not rp.truncated
        assert sorted(rp.requests) == [0, 2, 3]


# ------------------------------------------------------- replay machine
class TestReplayStateMachine:
    def test_admit_resets_fresh_but_not_restore(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        req = _mk_req(1, tokens=[5, 6])
        w.submit(req)
        w.admit(req, restore=False)
        w.checkpoint(1, [req])
        w.admit(req, restore=False)     # fresh re-admission: reset
        w.close()
        assert replay_journal(d).requests[1].n_tokens == 0
        w2 = JournalWriter(str(tmp_path / "b"))
        w2.submit(req)
        w2.admit(req, restore=False)
        w2.checkpoint(1, [req])
        w2.admit(req, restore=True)     # restore: progress survives
        w2.close()
        assert replay_journal(str(tmp_path / "b")).requests[1].n_tokens \
            == 2

    def test_dead_letter_round_trip(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        rec = RequestFailed(rid=3, tenant="t", reason="boom", boundary=7,
                            retries=4, site="alloc", ckpt_tokens=2)
        w.dead_letter(rec.record())
        lost = ReplicaLost(rid=4, tenant="t", reason="gone", boundary=8,
                           retries=1, site="replica_crash",
                           ckpt_tokens=0, replica="r1")
        w.dead_letter(lost.record())
        w.close()
        rr = RestartRecovery(d)
        f3 = rr._failure(rr.replay.requests[3].failure)
        f4 = rr._failure(rr.replay.requests[4].failure)
        assert f3 == rec
        assert isinstance(f4, ReplicaLost) and f4 == lost

    def test_replay_is_idempotent(self, tmp_path):
        d = str(tmp_path)
        w = JournalWriter(d)
        req = _mk_req(1, tokens=[5])
        w.submit(req)
        w.admit(req, restore=False)
        w.checkpoint(1, [req])
        w.close()
        assert replay_journal(d).state() == replay_journal(d).state()

    def test_cluster_merge_prefers_terminal(self, tmp_path):
        """The same rid running in one replica stream and completed in
        another (post-migration) merges to completed, with the SUBMIT
        meta grafted across streams."""
        d = str(tmp_path)
        req = _mk_req(1, tokens=[9, 9])
        w0 = JournalWriter(os.path.join(d, "r0"))
        w0.submit(req)
        w0.admit(req, restore=False)
        w0.checkpoint(1, [req])
        w0.close()
        w1 = JournalWriter(os.path.join(d, "r1"))
        w1.admit(req, restore=True)     # migrated: no SUBMIT here
        w1.complete(req)
        w1.close()
        rp = replay_journal(d)
        r = rp.requests[1]
        assert r.status == "completed"
        assert r.tokens == [9, 9]
        assert r.prompt == [0, 1, 2, 3]     # grafted from r0's SUBMIT

    def test_image_save_load_round_trip_bfloat16(self, tmp_path):
        import ml_dtypes
        path = str(tmp_path / "img-00000000.npz")
        k = np.arange(24, dtype=np.float32).reshape(2, 3, 4) \
            .astype(ml_dtypes.bfloat16)
        v = -k
        _save_image(path, k, v)
        k2, v2 = _load_image(path)
        assert k2.dtype == k.dtype and k2.shape == k.shape
        assert bytes(k2.tobytes()) == bytes(k.tobytes())
        assert bytes(v2.tobytes()) == bytes(v.tobytes())


# --------------------------------------------------- DurabilityPolicy
class TestDurabilityPolicy:
    def test_validation(self, tmp_path):
        with pytest.raises(ValueError):
            DurabilityPolicy(enabled=True)          # no journal_dir
        with pytest.raises(ValueError):
            DurabilityPolicy(fsync_boundaries=0)
        with pytest.raises(ValueError):
            DurabilityPolicy(segment_bytes=16)
        DurabilityPolicy(enabled=True, journal_dir=str(tmp_path))

    def test_plan_round_trip_and_provenance(self, tmp_path):
        pol = DurabilityPolicy(enabled=True, journal_dir=str(tmp_path),
                               fsync_boundaries=4, segment_bytes=4096)
        plan = ServingPlan(durability=pol)
        back = ServingPlan.from_dict(json.loads(
            json.dumps(plan.to_dict())))
        assert back.durability == pol
        # unknown durability keys dropped, missing defaulted
        d = plan.to_dict()
        d["durability"]["flux_capacitor"] = 1
        del d["durability"]["segment_bytes"]
        back2 = ServingPlan.from_dict(d)
        assert back2.durability.segment_bytes \
            == DurabilityPolicy().segment_bytes
        assert ServingPlan().durability == DurabilityPolicy()

    def test_resolve_records_provenance(self, tmp_path):
        from repro.configs.registry import get_config
        cfg = get_config("qwen2_7b", smoke=True)
        p1 = ServingPlan.resolve(cfg, slots=2, max_prompt_len=16,
                                 max_new_tokens=8)
        assert p1.provenance["durability"] == "default"
        pol = DurabilityPolicy(enabled=True, journal_dir=str(tmp_path))
        p2 = ServingPlan.resolve(cfg, slots=2, max_prompt_len=16,
                                 max_new_tokens=8, durability=pol)
        assert p2.provenance["durability"] == "explicit"
        assert p2.durability == pol


# ------------------------------------------------------- end to end
_E2E = {}       # compile cache: one model, engines per pool geometry


def _engine(n_pages=8, durability=None):
    if "model" not in _E2E:
        from repro.configs.registry import get_config
        from repro.models.api import build_model
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        _E2E["model"] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    cfg, model, params = _E2E["model"]
    key = n_pages
    if key not in _E2E:
        pcfg = PagedCacheConfig(page_size=8, n_pages=n_pages,
                                max_slots=2, max_blocks=5, segment_len=4)
        _E2E[key] = PagedServingEngine(model, pcfg)
    eng = _E2E[key]
    if durability is not None:
        plan = dataclasses.replace(eng.plan, durability=durability)
        # share the compiled entry points: from_plan only re-reads plan
        # geometry, which is identical here
        eng = PagedServingEngine.from_plan(model, plan)
        eng._prefill = _E2E[key]._prefill
        eng._write_pages = _E2E[key]._write_pages
        eng._admit_batch = _E2E[key]._admit_batch
        eng._segment = _E2E[key]._segment
    return cfg, model, params, eng


def _burst(cfg, n=3, gen=24):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=12)
                    .astype(np.int32), max_new_tokens=gen)
            for i in range(n)]


def _oracle():
    if "oracle" not in _E2E:
        cfg, _, params, eng = _engine()
        reqs = _burst(cfg)
        eng.run(reqs, params)
        _E2E["oracle"] = {r.rid: list(r.tokens) for r in reqs}
    return _E2E["oracle"]


class TestCrashRestart:
    def test_fault_free_journaled_run_replays_completed(self, tmp_path):
        d = str(tmp_path)
        pol = DurabilityPolicy(enabled=True, journal_dir=d)
        cfg, _, params, eng = _engine(durability=pol)
        reqs = _burst(cfg)
        stats = eng.run(reqs, params)
        assert stats["journal"]["n_appended"] > 0
        rp = replay_journal(d)
        assert not rp.truncated
        assert all(r.status == "completed"
                   for r in rp.requests.values())
        assert {rid: r.tokens for rid, r in rp.requests.items()} \
            == _oracle()
        assert not [f for f in os.listdir(d) if f.startswith("img-")]

    def test_crash_restart_bit_identical(self, tmp_path):
        """kill at a mid-burst boundary (preemptions in flight), cold
        restart from plan.json + journal: every request finishes with
        exactly the oracle's tokens, no images leak, and a second replay
        shows every request terminal."""
        d = str(tmp_path)
        pol = DurabilityPolicy(enabled=True, journal_dir=d)
        cfg, model, params, eng = _engine(durability=pol)
        with pytest.raises(ProcessCrashed):
            eng.run(_burst(cfg), params,
                    faults=FaultPlan.at(process_crash=5))
        rr = RestartRecovery(d)
        out = rr.resume(model, params, engine=_engine()[3])
        got = {r.rid: list(r.tokens) for r in out["requests"]
               if r.failure is None}
        assert got == _oracle()
        assert not [f for f in os.listdir(d) if f.startswith("img-")]
        rp = replay_journal(d)
        assert all(r.status in ("completed", "dead")
                   for r in rp.requests.values())

    def test_truncated_tail_degrades_to_restart(self, tmp_path):
        """Chop bytes off the post-crash journal tail: replay drops the
        damage and recovery still finishes bit-identical (the lost
        records were progress markers, not acknowledgements... unless a
        SUBMIT is lost, in which case the request was never acked and is
        legitimately absent)."""
        d = str(tmp_path)
        pol = DurabilityPolicy(enabled=True, journal_dir=d)
        cfg, model, params, eng = _engine(durability=pol)
        with pytest.raises(ProcessCrashed):
            eng.run(_burst(cfg), params,
                    faults=FaultPlan.at(process_crash=5))
        seg = sorted(f for f in os.listdir(d)
                     if f.startswith("wal-"))[-1]
        path = os.path.join(d, seg)
        with open(path, "r+b") as f:
            f.truncate(max(0, os.path.getsize(path) - 7))
        rr = RestartRecovery(d)
        acked = set(rr.replay.requests)
        out = rr.resume(model, params, engine=_engine()[3])
        got = {r.rid: list(r.tokens) for r in out["requests"]
               if r.failure is None}
        oracle = _oracle()
        assert got == {rid: oracle[rid] for rid in acked}

    def test_resume_journals_into_same_dir(self, tmp_path):
        """A crash DURING recovery recovers too: the resumed run appends
        to the same journal, so a second replay sees the completions."""
        d = str(tmp_path)
        pol = DurabilityPolicy(enabled=True, journal_dir=d)
        cfg, model, params, eng = _engine(durability=pol)
        with pytest.raises(ProcessCrashed):
            eng.run(_burst(cfg), params,
                    faults=FaultPlan.at(process_crash=3))
        n_before = replay_journal(d).n_records
        RestartRecovery(d).resume(model, params, engine=_engine()[3])
        rp = replay_journal(d)
        assert rp.n_records > n_before
        out2 = RestartRecovery(d).resume(model, params,
                                         engine=_engine()[3])
        c = out2["recovered"]
        assert c["replayed_completed"] + c["replayed_dead"] \
            == len(rp.requests)

    def test_cluster_crash_restart_bit_identical(self, tmp_path):
        d = str(tmp_path)
        cfg, model, params, eng = _engine()
        oracle_reqs = _burst(cfg, n=5)
        cl0 = ServingCluster(eng, params, n_replicas=2)
        cl0.run(oracle_reqs)
        oracle = {r.rid: list(r.tokens) for r in oracle_reqs}
        pol = DurabilityPolicy(enabled=True, journal_dir=d)
        deng = _engine(durability=pol)[3]
        cl = ServingCluster(deng, params, n_replicas=2,
                            faults=FaultPlan.at(process_crash=4))
        with pytest.raises(ProcessCrashed):
            cl.run(_burst(cfg, n=5))
        assert os.path.isdir(os.path.join(d, "r0"))
        out = RestartRecovery(d).resume(model, params)
        got = {r.rid: list(r.tokens) for r in out["requests"]
               if r.failure is None}
        assert got == oracle
