"""Core MetaML framework: meta-model, pipe tasks, flow executor, search."""

import math

import pytest

from repro.core.flow import DesignFlow, FlowError
from repro.core.metamodel import LEVEL_DNN, MetaModel, ModelArtifact
from repro.core.search import (binary_search_max, greedy_lattice_descent,
                               monotone_shrink_search)
from repro.core.task import LambdaTask, OTask, TaskError


class Gen(LambdaTask):
    n_in, n_out = 0, 1
    defaults = {"value": 1}

    def execute(self, meta, inputs):
        return [meta.add_model("gen", LEVEL_DNN,
                               {"v": self.param(meta, "value")})]


class Inc(OTask):
    n_in, n_out = 1, 1
    defaults = {"by": 1}

    def execute(self, meta, inputs):
        v = meta.model(inputs[0]).payload["v"]
        return [meta.add_model("inc", LEVEL_DNN,
                               {"v": v + self.param(meta, "by")},
                               parent=inputs[0])]


# ------------------------------------------------------------- MetaModel
class TestMetaModel:
    def test_cfg_store(self):
        m = MetaModel({"a": 1})
        m.set("b", 2)
        assert m.get("a") == 1 and m.get("b") == 2
        assert m.get("missing", 42) == 42

    def test_model_space_and_lineage(self):
        m = MetaModel()
        a = m.add_model("root", LEVEL_DNN, {})
        b = m.add_model("child", LEVEL_DNN, {}, parent=a)
        c = m.add_model("grand", LEVEL_DNN, {}, parent=b)
        assert m.lineage(c) == [c, b, a]
        assert a in m and "nope" not in m

    def test_latest_and_levels(self):
        m = MetaModel()
        m.add_model("x", "dnn", {})
        n2 = m.add_model("y", "lowered", {})
        assert m.latest("lowered").name == n2
        assert len(list(m.models("dnn"))) == 1

    def test_log_trace(self):
        m = MetaModel()
        m.record("task.start", task="t")
        m.record("other", x=1)
        assert len(m.trace("task.")) == 1


# ------------------------------------------------------------ pipe tasks
class TestPipeTasks:
    def test_param_priority_cfg_over_instance_over_default(self):
        t = Inc(by=5)
        meta = MetaModel()
        assert t.param(meta, "by") == 5
        meta.set("Inc.by", 9)
        assert t.param(meta, "by") == 9
        assert Inc().param(MetaModel(), "by") == 1

    def test_unknown_param_rejected(self):
        with pytest.raises(TaskError):
            Inc(nope=1)

    def test_multiplicity_enforced(self):
        meta = MetaModel()
        with pytest.raises(TaskError):
            Inc().run(meta, [])


# ------------------------------------------------------------------ flow
class TestFlow:
    def test_linear_flow(self):
        flow = DesignFlow("t")
        flow.chain(Gen(value=10), Inc(by=2), Inc(by=3))
        meta = flow.execute()
        assert meta.latest().payload["v"] == 15

    def test_validate_rejects_dangling_input(self):
        flow = DesignFlow("bad")
        flow.add(Inc())          # 1 input declared, 0 edges
        with pytest.raises(FlowError):
            flow.execute()

    def test_cycle_with_condition_terminates(self):
        # Gen -> Inc -> (back to Inc while v < 5)
        flow = DesignFlow("loop")
        g = flow.add(Gen(value=0))
        i = flow.add(Inc(by=1))
        flow.connect(g, i)
        flow.connect(i, i, condition=lambda meta, outs:
                     meta.model(outs[0]).payload["v"] < 5)
        meta = flow.execute()
        assert meta.latest().payload["v"] == 5

    def test_unbounded_cycle_raises(self):
        flow = DesignFlow("inf")
        g = flow.add(Gen())
        i = flow.add(Inc())
        flow.connect(g, i)
        flow.connect(i, i)  # no condition: infinite
        with pytest.raises(FlowError):
            flow.execute(max_steps=20)

    def test_to_dot(self):
        flow = DesignFlow("viz")
        flow.chain(Gen(), Inc())
        dot = flow.to_dot()
        assert "digraph" in dot and "Gen" in dot and "Inc" in dot

    def test_flow_records_trace(self):
        flow = DesignFlow("tr")
        flow.chain(Gen(), Inc())
        meta = flow.execute()
        events = [e["event"] for e in meta.log]
        assert "flow.start" in events and "flow.done" in events
        assert events.count("task.done") == 2


# ---------------------------------------------------------------- search
class TestSearch:
    def test_binary_search_finds_boundary(self):
        # feasible iff x <= 0.7
        def f(x):
            return x <= 0.7, x, {}
        res = binary_search_max(f, beta=0.01)
        assert abs(res.best_x - 0.7) <= 0.01

    def test_binary_search_step_count(self):
        # paper: 1 + log2(1/beta) bisection steps (+1 for the hi probe)
        def f(x):
            return x <= 0.5, x, {}
        beta = 0.02
        res = binary_search_max(f, beta=beta)
        expected_bisect = math.ceil(math.log2(1 / beta))
        assert res.n_steps <= 2 + expected_bisect + 1

    def test_binary_search_all_feasible_early_exit(self):
        res = binary_search_max(lambda x: (True, x, {}), beta=0.02)
        assert res.best_x == 1.0 and res.n_steps == 2

    def test_binary_search_none_feasible(self):
        res = binary_search_max(lambda x: (x <= 0.0, x, {}), beta=0.1)
        assert res.best_x == 0.0

    def test_monotone_shrink_stops_at_first_infeasible(self):
        cands = [0.7, 0.5, 0.35, 0.25]
        calls = []

        def f(x):
            calls.append(x)
            return x >= 0.4, -x, {}
        res = monotone_shrink_search(cands, f)
        assert res.best_x == 0.5
        assert calls == [0.7, 0.5, 0.35]  # stopped at first infeasible

    def test_greedy_lattice(self):
        # items may descend to "mid" but not "low"
        def accept(assign):
            ok = all(v != "low" for v in assign.values())
            return ok, 0.0, {}
        assign, res = greedy_lattice_descent(
            ["a", "b"], ["high", "mid", "low"], accept, "high", passes=3)
        assert assign == {"a": "mid", "b": "mid"}
