"""ServingPlan: round-trip compat contract, provenance-tracked resolve,
from_plan construction equivalence, staged-search pruning, and the SERVE
O-task's deterministic search path (stub scorer — no engine replay)."""

import json
import zlib

import pytest

from repro.core.metamodel import MetaModel
from repro.core.search import staged_search
from repro.core.task import TaskError
from repro.serving import (HealthPolicy, PagedCacheConfig,
                           PagedServingEngine, ServingPlan,
                           TenantConfig, TrafficProfile)
from repro.tasks.model_gen import ModelGen
from repro.tasks.serve import Serve, candidate_grid

ARCH = "qwen2-7b"               # the paged-eligible smoke shape


@pytest.fixture(scope="module")
def lm_meta():
    """One ModelGen artifact shared by every SERVE-task test here."""
    meta = MetaModel()
    (name,) = ModelGen(model=ARCH, train_en=False, smoke=True).run(
        meta, [])
    return meta, name


# ------------------------------------------------------------ round-trip
class TestServingPlanRoundTrip:
    def mk_plan(self):
        return ServingPlan(
            arch=ARCH,
            cache=PagedCacheConfig(page_size=8, n_pages=25, max_slots=3,
                                   max_blocks=8, segment_len=4,
                                   growth_pages=2, retain_pages=3),
            prefill_mode="batched", cache_dtype="float32",
            tenants=(TenantConfig("svc", weight=2.0, page_budget=12),
                     TenantConfig("batch")),
            n_replicas=3, health=HealthPolicy(suspect_after=1,
                                              dead_after=2),
            max_prompt_len=40, max_new_tokens=12,
            provenance={"page_size": "tuned", "segment_len": "default"})

    def test_json_roundtrip_is_identity(self):
        plan = self.mk_plan()
        back = ServingPlan.from_dict(json.loads(json.dumps(
            plan.to_dict())))
        assert back == plan

    def test_unknown_keys_dropped_every_level(self):
        d = self.mk_plan().to_dict()
        d["future_knob"] = 99
        d["cache"]["future_cache_knob"] = 7
        d["tenants"][0]["future_tenant_knob"] = "x"
        d["health"]["future_health_knob"] = 1
        assert ServingPlan.from_dict(d) == self.mk_plan()

    def test_missing_keys_defaulted_every_level(self):
        d = self.mk_plan().to_dict()
        del d["n_replicas"], d["provenance"]
        del d["cache"]["growth_pages"]
        del d["health"]["dead_after"]
        back = ServingPlan.from_dict(d)
        assert back.n_replicas == 1
        assert back.provenance == {}
        assert back.cache.growth_pages == 0
        assert back.health.dead_after == HealthPolicy().dead_after
        # everything not deleted survives
        assert back.cache.page_size == 8
        assert back.tenants[0].page_budget == 12

    def test_validation(self):
        with pytest.raises(ValueError):
            ServingPlan(prefill_mode="streaming")
        with pytest.raises(ValueError):
            ServingPlan(n_replicas=0)
        with pytest.raises(ValueError):
            HealthPolicy(suspect_after=3, dead_after=2)

    def test_sharing_requires_batched_prefill(self):
        assert ServingPlan().sharing
        assert not ServingPlan(prefill_mode="serial").sharing
        off = PagedCacheConfig(enable_prefix_sharing=False)
        assert not ServingPlan(cache=off).sharing


# --------------------------------------------------------------- resolve
class TestResolve:
    def test_cold_cache_default_provenance_and_geometry(self, tmp_path):
        from repro.configs.registry import get_config
        cfg = get_config(ARCH, smoke=True)
        cold = str(tmp_path / "empty_cache.json")
        plan = ServingPlan.resolve(cfg, slots=4, max_prompt_len=32,
                                   max_new_tokens=16, cache_path=cold)
        assert plan.provenance["page_size"] == "default"
        assert plan.provenance["segment_len"] == "default"
        cap = 32 + 16 + 1
        blocks = -(-cap // plan.cache.page_size)
        assert plan.cache.max_blocks == blocks
        assert plan.cache.n_pages == 4 * blocks + 1
        assert plan.cache.max_slots == 4
        assert plan.arch == cfg.name

    def test_explicit_cap_and_overrides(self, tmp_path):
        from repro.configs.registry import get_config
        cfg = get_config(ARCH, smoke=True)
        cold = str(tmp_path / "empty_cache.json")
        plan = ServingPlan.resolve(cfg, slots=2, max_prompt_len=16,
                                   max_new_tokens=8, segment_len=4,
                                   page_size_cap=8, cache_path=cold,
                                   prefill_bucket=2)
        assert plan.cache.page_size <= 8
        assert plan.provenance["page_size"] == "capped"
        assert plan.provenance["segment_len"] == "explicit"
        assert plan.provenance["prefill_bucket"] == "explicit"
        assert plan.cache.prefill_bucket == 2
        # pool geometry re-derived against the capped page size
        cap = 16 + 8 + 1
        assert plan.cache.max_blocks == -(-cap // plan.cache.page_size)

    def test_pool_slots_oversubscription(self, tmp_path):
        from repro.configs.registry import get_config
        cfg = get_config(ARCH, smoke=True)
        cold = str(tmp_path / "empty_cache.json")
        plan = ServingPlan.resolve(cfg, slots=4, pool_slots=2,
                                   max_prompt_len=16, max_new_tokens=8,
                                   cache_path=cold)
        assert plan.cache.max_slots == 4
        assert plan.cache.n_pages == 2 * plan.cache.max_blocks + 1


# ------------------------------------------------------------- from_plan
class TestFromPlan:
    def test_engine_from_plan_matches_kwargs_engine(self, lm_meta):
        meta, name = lm_meta
        model = meta.model(name).payload.model
        pcfg = PagedCacheConfig(page_size=8, n_pages=13, max_slots=2,
                                max_blocks=6, segment_len=4)
        tenants = [TenantConfig("svc", weight=2.0, page_budget=6)]
        kw = PagedServingEngine(model, pcfg, tenants=tenants)
        plan = kw.plan
        assert plan.cache == pcfg
        assert plan.tenants == tuple(tenants)
        via_plan = PagedServingEngine.from_plan(model, plan)
        assert via_plan.plan == plan
        assert via_plan.pcfg == pcfg
        assert via_plan.cache_dtype == kw.cache_dtype
        assert via_plan.sharing == kw.sharing
        assert via_plan.tenants == kw.tenants

    def test_loaded_artifact_deploys_bit_exact(self, lm_meta):
        meta, name = lm_meta
        model = meta.model(name).payload.model
        pcfg = PagedCacheConfig(page_size=8, n_pages=13, max_slots=2,
                                max_blocks=6, segment_len=4)
        plan = ServingPlan(arch=ARCH, cache=pcfg, cache_dtype="float32")
        loaded = ServingPlan.from_dict(json.loads(json.dumps(
            plan.to_dict())))
        eng = PagedServingEngine.from_plan(model, loaded)
        assert eng.plan == plan
        assert eng.pcfg == pcfg
        assert eng.cache_dtype.name == "float32"


# ---------------------------------------------------------- staged search
class TestStagedSearch:
    def test_pruned_candidate_never_runs_stage2(self):
        stage2_calls = []

        def s1(x):
            return True, float(-x), {"feat": x}

        def s2(x):
            stage2_calls.append(x)
            return True, float(x), {}

        cands = list(range(8))
        res = staged_search(cands, s1, s2, keep=3)
        # stage 1 favors small x: exactly {0, 1, 2} reach stage 2
        assert sorted(stage2_calls) == [0, 1, 2]
        assert res.best_x == 2          # stage-2 objective favors large
        stage1 = [s for s in res.steps if s.info["stage"] == 1]
        stage2 = [s for s in res.steps if s.info["stage"] == 2]
        assert len(stage1) == len(cands) and len(stage2) == 3
        for x in (3, 4, 5, 6, 7):       # pruned: only a stage-1 step
            assert x not in {s.x for s in stage2}

    def test_must_keep_promotes_past_pruning(self):
        stage2_calls = []

        def s1(x):
            return True, float(x), {}

        def s2(x):
            stage2_calls.append(x)
            return True, float(x), {}

        staged_search(list(range(8)), s1, s2, keep=2, must_keep=(0,))
        assert sorted(stage2_calls) == [0, 6, 7]

    def test_stage1_infeasible_never_reaches_stage2(self):
        def s1(x):
            return x % 2 == 0, float(x), {}

        def s2(x):
            return True, float(x), {}

        res = staged_search(list(range(6)), s1, s2, keep=6)
        stage2 = {s.x for s in res.steps if s.info["stage"] == 2}
        assert stage2 == {0, 2, 4}
        assert res.best_x == 4

    def test_no_feasible_stage2_returns_none(self):
        res = staged_search([1, 2], lambda x: (True, 0.0, {}),
                            lambda x: (False, 0.0, {}), keep=2)
        assert res.best_x is None


# ------------------------------------------------------------ SERVE task
def stub_scorer(plan, stage):
    """Deterministic pure-host fitness: a CRC of the effective cache
    config — stable across processes (unlike hash()) and distinct per
    candidate."""
    key = json.dumps(plan.cache.to_dict(), sort_keys=True)
    score = float(zlib.crc32(f"{key}@{stage}".encode()) % 10_000)
    return True, score, {"stub": True}


class TestServeTask:
    def test_search_is_deterministic_and_gated(self, lm_meta, tmp_path):
        meta, name = lm_meta
        art = str(tmp_path / "plan.json")
        cold = str(tmp_path / "empty_cache.json")
        results = []
        for _ in range(2):
            m = MetaModel()
            # reuse the built artifact: determinism is about the search,
            # not ModelGen
            m.put(meta.model(name))
            task = Serve(scorer=stub_scorer, slots=2, cache_path=cold,
                         artifact_path=art)
            (out,) = task.run(m, [name])
            results.append(m.get("serve.result"))
            assert "+V" in out
            assert m.model(out).payload.meta["serving_plan"] \
                == results[-1]["plan"]
        assert results[0] == results[1]
        res = results[0]
        # stage-1 pruning skipped at least half the grid's stage-2 runs
        assert res["n_stage2"] * 2 <= res["n_candidates"]
        assert res["n_pruned"] == res["n_candidates"] - res["n_stage2"]
        # the default plan always reaches stage 2, so the winner is
        # never worse than it
        assert res["default_objective"] is not None
        assert res["objective"] >= res["default_objective"]
        # the emitted artifact is the winning plan, bit-exact
        with open(art) as f:
            assert ServingPlan.from_dict(json.load(f)) \
                == ServingPlan.from_dict(res["plan"])

    def test_grid_has_default_first_and_unique_candidates(self):
        plan = ServingPlan()
        grid = candidate_grid(plan)
        assert grid[0] == plan
        keys = [json.dumps(p.cache.to_dict(), sort_keys=True)
                for p in grid]
        assert len(set(keys)) == len(keys)
        # a moved page size re-derives the pool geometry; other one-knob
        # neighbors keep the base plan's geometry untouched
        for p in grid[1:]:
            if p.cache.page_size != plan.cache.page_size:
                assert p.cache.max_blocks \
                    == -(-p.cap_tokens // p.cache.page_size)
            else:
                assert (p.cache.n_pages, p.cache.max_blocks) \
                    == (plan.cache.n_pages, plan.cache.max_blocks)

    def test_rejects_unpaged_arch(self):
        meta = MetaModel()
        (name,) = ModelGen(model="h2o-danube-3-4b", train_en=False,
                           smoke=True).run(meta, [])
        with pytest.raises(TaskError):
            Serve(scorer=stub_scorer).run(meta, [name])


# --------------------------------------------------------------- traffic
class TestTrafficProfile:
    def test_requests_deterministic_and_prefix_aligned(self):
        prof = TrafficProfile(n_requests=5, prompt_len=24,
                              prefix_share=0.5, arrival_rate=3.0,
                              tenant_mix=(("a", 1.0), ("b", 2.0)),
                              seed=9)
        a = prof.requests(512, page_size=8)
        b = prof.requests(512, page_size=8)
        assert [(r.tenant, r.arrival) for r in a] \
            == [(r.tenant, r.arrival) for r in b]
        for ra, rb in zip(a, b):
            assert (ra.prompt == rb.prompt).all()
        # shared prefix: aligned down to whole pages, shared by all
        for r in a[1:]:
            assert (r.prompt[:8] == a[0].prompt[:8]).all()

    def test_roundtrip_and_scaled(self):
        prof = TrafficProfile(n_requests=9, arrival_rate=2.0,
                              tenant_mix=(("x", 1.0),), seed=3)
        back = TrafficProfile.from_dict(json.loads(json.dumps(
            {**prof.to_dict(), "future": 1})))
        assert back == prof
        small = prof.scaled(0.5)
        assert small.n_requests == 4 or small.n_requests == 5
        assert small.seed == prof.seed
        assert small.arrival_rate == prof.arrival_rate
