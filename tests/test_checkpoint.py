"""Checkpoint manager: atomic commit, retention, async, elastic restore."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "opt": {"step": jnp.asarray(3, jnp.int32)}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        ckpt.save(5, _state(1.5))
        state, meta = ckpt.restore()
        assert meta["step"] == 5
        np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                      np.full((4, 4), 1.5))

    def test_latest_of_many(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False, keep=10)
        for s in (1, 7, 3):
            ckpt.save(s, _state(float(s)))
        assert ckpt.latest_step() == 7
        state, _ = ckpt.restore(step=3)
        assert float(state["params"]["w"][0, 0]) == 3.0

    def test_retention_gc(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False, keep=2)
        for s in range(5):
            ckpt.save(s, _state())
        assert ckpt.committed_steps() == [3, 4]

    def test_async_save_then_wait(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=True)
        ckpt.save(1, _state(2.0))
        ckpt.wait()
        state, meta = ckpt.restore()
        assert meta["step"] == 1

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        """A crash mid-save (payload without marker) must be invisible."""
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        ckpt.save(1, _state(1.0))
        # simulate a torn save at step 2: directory exists, no marker
        os.makedirs(tmp_path / "step_00000002")
        with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
            f.write("{}")
        assert ckpt.latest_step() == 1
        state, meta = ckpt.restore()
        assert meta["step"] == 1

    def test_restore_empty_dir(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path))
        state, meta = ckpt.restore()
        assert state is None and meta is None

    def test_nested_tuple_state(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        state = {"a": [jnp.ones(2), jnp.zeros(3)]}
        ckpt.save(0, state)
        restored, _ = ckpt.restore()
        # lists round-trip as index-keyed dicts (documented layout)
        np.testing.assert_array_equal(np.asarray(restored["a"]["0"]),
                                      np.ones(2))
