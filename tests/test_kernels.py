"""Pallas kernels vs ref.py oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.sparsity.masks import block_map, block_mask

KEY = jax.random.PRNGKey(0)


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 512, 128), (256, 1024, 256),
                                       (128, 512, 384)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        x = jax.random.normal(KEY, (m, k)).astype(dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
        y = quant_matmul(x, w, interpret=True)
        r = ref.quant_matmul_ref(x, w)
        # bf16 inputs: XLA fusion differences flip occasional .5-rounding
        # boundaries in x/scale — allow one quantization LSB of slack
        atol = 1e-3 if dtype == jnp.float32 else 0.5
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=1e-4 if dtype == jnp.float32
                                   else 1e-2, atol=atol)

    def test_close_to_exact_matmul(self):
        x = jax.random.normal(KEY, (128, 512))
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
        y = quant_matmul(x, w, interpret=True)
        exact = x @ w
        rel = float(jnp.max(jnp.abs(y - exact))
                    / jnp.max(jnp.abs(exact)))
        assert rel < 0.05  # int8 path stays within quantization noise

    def test_small_m_adapts_tile(self):
        # m < BM: the tile shrinks to m and still matches the oracle
        x = jax.random.normal(KEY, (64, 512))
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
        y = quant_matmul(x, w, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.quant_matmul_ref(x, w)),
            rtol=1e-4, atol=1e-3)

    def test_non_tileable_raises(self):
        x = jax.random.normal(KEY, (130, 512))  # 130 % 128 != 0
        w = jax.random.normal(KEY, (512, 128))
        with pytest.raises(AssertionError):
            quant_matmul(x, w, interpret=True)


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv", [(128, 128), (256, 256), (130, 256),
                                        (256, 100)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, sq, skv, causal):
        if causal and sq != skv:
            pytest.skip("causal requires aligned positions here")
        b, h, d = 2, 4, 64
        q = jax.random.normal(KEY, (b, sq, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, h, d))
        y = flash_attention(q, k, v, causal=causal, interpret=True)
        r = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("kv_heads", [1, 2, 4])
    def test_gqa(self, kv_heads):
        b, s, h, d = 1, 128, 4, 32
        q = jax.random.normal(KEY, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv_heads, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv_heads, d))
        y = flash_attention(q, k, v, causal=True, interpret=True)
        r = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        b, s, h, d = 1, 256, 2, 32
        q = jax.random.normal(KEY, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        y = flash_attention(q, k, v, causal=True, window=window,
                            interpret=True)
        r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        b, s, h, d = 1, 128, 2, 64
        q = jax.random.normal(KEY, (b, s, h, d)).astype(dtype)
        k = jax.random.normal(jax.random.PRNGKey(1),
                              (b, s, h, d)).astype(dtype)
        v = jax.random.normal(jax.random.PRNGKey(2),
                              (b, s, h, d)).astype(dtype)
        y = flash_attention(q, k, v, causal=True, interpret=True)
        assert y.dtype == dtype
        r = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol)


class TestBlockSparseMatmul:
    @pytest.mark.parametrize("rate", [0.0, 0.25, 0.5, 0.75])
    def test_matches_dense_over_masked(self, rate):
        m, k, n = 256, 512, 384
        x = jax.random.normal(KEY, (m, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        mask = block_mask(w, rate=rate, block=128)
        wm = w * mask
        kidx = jnp.asarray(compact_block_index(
            block_map(np.asarray(mask), 128)))
        y = block_sparse_matmul(x, wm, kidx, interpret=True)
        r = ref.block_sparse_matmul_ref(x, wm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=1e-4, atol=1e-3)

    def test_trip_count_shrinks_with_sparsity(self):
        k, n = 512, 512
        w = jax.random.normal(KEY, (k, n))
        mask = block_mask(w, rate=0.75, block=128)
        kidx = compact_block_index(block_map(np.asarray(mask), 128))
        assert kidx.shape[1] < k // 128  # fewer trips than dense

    def test_masked_matmul_wrapper(self):
        m, k, n = 128, 256, 256
        x = jax.random.normal(KEY, (m, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        mask = block_mask(w, rate=0.5, block=128)
        y = ops.masked_matmul(x, w, mask, interpret=True)
        r = x @ (w * mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=1e-4, atol=1e-3)
