"""Elastic scaling: checkpoints restore onto a DIFFERENT mesh shape with
correct values and the new sharding (the restart-with-fewer/more-nodes
path).  Runs in a subprocess with 8 placeholder devices."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.registry import get_config
    from repro.models.api import build_model
    from repro.optim.optimizers import adamw
    from repro.parallel.sharding import ShardingRules
    from repro.runtime.train_loop import (init_train_state,
                                          make_train_step, state_shardings)

    ckpt_dir = os.environ["CKPT_DIR"]
    cfg = get_config("qwen2_7b", smoke=True)
    opt = adamw(1e-3)

    # ---- phase 1: train 2 steps on a 2x4 mesh, checkpoint
    mesh_a = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    model_a = build_model(cfg, mesh=mesh_a)
    rules_a = ShardingRules.default(mesh_a)
    with mesh_a:
        state = init_train_state(model_a, opt, jax.random.PRNGKey(0))
        sh_a = state_shardings(model_a, rules_a, "adamw")
        state = jax.device_put(state, sh_a)
        step = jax.jit(make_train_step(model_a, opt))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        for _ in range(2):
            state, metrics = step(state, batch)
        loss_a = float(metrics["loss"])
    ckpt = CheckpointManager(ckpt_dir, async_save=False)
    ckpt.save(1, state)

    # ---- phase 2: restore onto a 4x2 mesh ("elastic" reshape), continue
    mesh_b = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    model_b = build_model(cfg, mesh=mesh_b)
    rules_b = ShardingRules.default(mesh_b)
    with mesh_b:
        sh_b = state_shardings(model_b, rules_b, "adamw")
        from repro.checkpoint.manager import _flatten
        flat_sh = _flatten(sh_b)
        restored, meta = ckpt.restore(shardings=flat_sh)
        # values identical to the saved state
        import numpy as np
        a = _flatten(jax.device_get(state))
        b = _flatten(jax.device_get(restored))
        max_err = max(float(np.max(np.abs(np.asarray(a[k], np.float32)
                                          - np.asarray(b[k], np.float32))))
                      for k in a)
        # and the loop continues on the new mesh
        step_b = jax.jit(make_train_step(model_b, opt))
        restored, metrics = step_b(restored, batch)
        loss_b = float(metrics["loss"])
    print(f"RESULT {max_err} {loss_a} {loss_b}")
""")


@pytest.mark.slow
def test_checkpoint_restores_across_mesh_shapes(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
               CKPT_DIR=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    max_err, loss_a, loss_b = (float(t) for t in line.split()[1:])
    assert max_err == 0.0  # bit-exact restore across mesh shapes
    assert loss_b < loss_a + 1.0  # training continues sanely
