"""Fault-tolerant training loop + data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import ShardedBatcher, TokenSource
from repro.models.api import build_model
from repro.optim.optimizers import adamw
from repro.runtime.train_loop import (FailureInjector, train_loop)


def _setup(tmp_path, vocab=256):
    cfg = get_config("qwen2_7b", smoke=True)
    model = build_model(cfg)
    source = TokenSource(cfg.vocab_size, batch=4, seq_len=32)
    batcher = ShardedBatcher(source, rules=None, prefetch=False)
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    return model, batcher, ckpt


class TestDataPipeline:
    def test_step_deterministic(self):
        s = TokenSource(256, batch=4, seq_len=16)
        b1 = s.batch_at(7)
        b2 = s.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = s.batch_at(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        s = TokenSource(256, batch=2, seq_len=16)
        b = s.batch_at(0)
        # labels[i] must continue tokens[i] by one position in the stream
        tok, lab = b["tokens"][0], b["labels"][0]
        np.testing.assert_array_equal(tok[1:], lab[:-1])

    def test_prefetch_matches_sync(self):
        s = TokenSource(256, batch=2, seq_len=8)
        sync = ShardedBatcher(s, None, prefetch=False)
        pre = ShardedBatcher(s, None, prefetch=True)
        for step in range(4):
            a = sync.get(step)
            b = pre.get(step)
            np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                          np.asarray(b["tokens"]))


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        model, batcher, ckpt = _setup(tmp_path)
        report = train_loop(model, steps=12, batcher=batcher, ckpt=ckpt,
                            optimizer=adamw(3e-3), ckpt_every=6)
        assert report.steps_run == 12
        assert report.losses[-1] < report.losses[0]

    def test_failure_recovery_matches_uninterrupted(self, tmp_path):
        """Restart-from-checkpoint + deterministic data ⇒ identical
        trajectory to an uninterrupted run."""
        model, batcher, ckpt1 = _setup(tmp_path / "a")
        r1 = train_loop(model, steps=10, batcher=batcher, ckpt=ckpt1,
                        optimizer=adamw(1e-3), ckpt_every=5)
        _, batcher2, ckpt2 = _setup(tmp_path / "b")
        r2 = train_loop(model, steps=10, batcher=batcher2, ckpt=ckpt2,
                        optimizer=adamw(1e-3), ckpt_every=5,
                        injector=FailureInjector((7,)))
        assert r2.restarts == 1
        assert abs(r1.final_loss - r2.final_loss) < 1e-5

    def test_resume_after_stop(self, tmp_path):
        """A fresh loop over the same ckpt dir continues, not restarts."""
        model, batcher, ckpt = _setup(tmp_path)
        train_loop(model, steps=6, batcher=batcher, ckpt=ckpt,
                   optimizer=adamw(1e-3), ckpt_every=3)
        report = train_loop(model, steps=10, batcher=batcher, ckpt=ckpt,
                            optimizer=adamw(1e-3), ckpt_every=3)
        assert report.steps_run == 4  # only steps 6..9

    def test_grad_compression_trains(self, tmp_path):
        model, batcher, ckpt = _setup(tmp_path)
        report = train_loop(model, steps=8, batcher=batcher, ckpt=ckpt,
                            optimizer=adamw(3e-3), ckpt_every=8,
                            grad_compression=True)
        assert np.isfinite(report.final_loss)
        assert report.losses[-1] < report.losses[0]


class TestOptimizers:
    def test_adamw_matches_reference_step(self):
        from repro.optim.optimizers import adamw as mk, apply_updates
        opt = mk(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
        p = {"w": jnp.asarray([1.0, -2.0])}
        g = {"w": jnp.asarray([0.5, 0.5])}
        st = opt.init(p)
        up, st = opt.update(g, st, p)
        # first adam step with bias correction: update = -lr * g/|g| (elem)
        np.testing.assert_allclose(np.asarray(up["w"]),
                                   [-0.1, -0.1], rtol=1e-5)

    def test_clip_by_global_norm(self):
        from repro.optim.optimizers import clip_by_global_norm
        g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 5.0) < 1e-6
        total = np.sqrt(float(clipped["a"][0] ** 2 + clipped["b"][0] ** 2))
        assert abs(total - 1.0) < 1e-5

    def test_cosine_schedule_shape(self):
        from repro.optim.optimizers import cosine_schedule
        s = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
        assert float(s(jnp.asarray(5))) < 1.0          # warming up
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
        assert float(s(jnp.asarray(100))) < 0.2        # decayed
