"""Fault injection + request-level recovery: FaultPlan determinism and
termination, typed allocator errors, RecoveryManager bookkeeping
(quarantine/backoff/dead-letter/shedding/swap integrity/invariants), the
watchdog, and end-to-end chaos runs that must complete bit-identical to
the fault-free baseline."""

import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.serving import (AllocatorError, ContinuousBatchingScheduler,
                           ENGINE_SITES, EngineStalledError, FAULT_SITES,
                           FaultPlan, FaultSpec, InjectedFault,
                           PageAllocator, PagedCacheConfig,
                           RecoveryManager, RecoveryPolicy, Request,
                           RequestFailed, SwapState,
                           diagnostic_snapshot)
from repro.serving.faults import corrupt_image, image_checksum


# -------------------------------------------------------------- FaultPlan
class TestFaultPlan:
    def test_replay_is_bit_exact(self):
        """Two plans with identical seed+specs make identical decisions
        over any opportunity sequence — the property chaos CI rests on."""
        mk = lambda: FaultPlan.seeded(7, rate=0.4, max_fires=3)  # noqa
        a, b = mk(), mk()
        sites = [FAULT_SITES[i % len(FAULT_SITES)] for i in range(200)]
        assert [a.should_fire(s) for s in sites] \
            == [b.should_fire(s) for s in sites]
        assert a.log == b.log

    def test_site_streams_are_independent(self):
        """Disarming sites never shifts another site's schedule: a subset
        plan fires the surviving sites at the same opportunities as the
        full plan (this is what makes fault-plan bisection work)."""
        full = FaultPlan.seeded(3, rate=0.3, max_fires=2)
        sub = FaultPlan.seeded(3, sites=("alloc",), rate=0.3, max_fires=2)
        for _ in range(100):
            for site in FAULT_SITES:
                full.should_fire(site)
                sub.should_fire(site)
        assert [e for e in full.log if e[0] == "alloc"] == sub.log

    def test_terminates_at_max_fires(self):
        plan = FaultPlan([FaultSpec(site="alloc", rate=1.0, max_fires=3)])
        fired = sum(plan.should_fire("alloc") for _ in range(50))
        assert fired == 3
        assert plan.total_fires == 3
        assert plan.opportunities["alloc"] == 50

    def test_at_schedules_exact_opportunity(self):
        plan = FaultPlan.at(alloc=2, decode_poison=0)
        hits = [k for k in range(6) if plan.should_fire("alloc")]
        assert hits == [2]
        assert plan.should_fire("decode_poison")
        assert plan.log == [("alloc", 2), ("decode_poison", 0)]

    def test_gate_raises_typed(self):
        plan = FaultPlan.at(dispatch_segment=0)
        with pytest.raises(InjectedFault) as ei:
            plan.gate("dispatch_segment")
        assert ei.value.site == "dispatch_segment"
        assert ei.value.opportunity == 0
        plan.gate("dispatch_segment")        # max_fires spent: no raise

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(site="nope")
        with pytest.raises(ValueError):
            FaultSpec(site="alloc", rate=1.5)
        with pytest.raises(ValueError):
            FaultSpec(site="alloc", max_fires=0)   # plans must terminate
        with pytest.raises(ValueError):
            FaultPlan([FaultSpec(site="alloc"), FaultSpec(site="alloc")])

    def test_summary_json_safe(self):
        import json
        plan = FaultPlan.at(alloc=0)
        plan.should_fire("alloc")
        s = json.loads(json.dumps(plan.summary()))
        assert s["fired"] == [["alloc", 0]]


# ------------------------------------------------------- image integrity
class TestImageIntegrity:
    def test_checksum_detects_corruption(self):
        rng = np.random.default_rng(0)
        k = rng.normal(size=(2, 3, 4)).astype(np.float32)
        v = rng.normal(size=(2, 3, 4)).astype(np.float32)
        crc = image_checksum(k, v)
        assert image_checksum(k, v) == crc
        bad = corrupt_image(k)
        assert bad.shape == k.shape and bad.dtype == k.dtype
        assert image_checksum(bad, v) != crc


# ------------------------------------------------------- typed allocator
class TestAllocatorErrors:
    def test_misuse_raises_allocator_error(self):
        a = PageAllocator(8)
        with pytest.raises(AllocatorError):
            a.alloc(-1)
        p = a.alloc(2)
        a.release(p)
        with pytest.raises(AllocatorError):
            a.release(p)                       # double free
        with pytest.raises(AllocatorError):
            a.share([p[0]])                    # sharing a free page
        assert issubclass(AllocatorError, ValueError)  # back-compat

    def test_checks_survive_python_O(self):
        """The misuse guards are raises, not asserts: they must fire
        under ``python -O`` too."""
        code = ("from repro.serving.paged_cache import PageAllocator, "
                "AllocatorError\n"
                "a = PageAllocator(4); p = a.alloc(2); a.release(p)\n"
                "try:\n    a.release(p)\nexcept AllocatorError:\n"
                "    raise SystemExit(0)\nraise SystemExit(1)\n")
        r = subprocess.run([sys.executable, "-O", "-c", code],
                           capture_output=True, text=True)
        assert r.returncode == 0, r.stderr

    def test_injected_alloc_failure(self):
        """An armed alloc site makes the pool look dry for exactly the
        scheduled opportunities; the allocator stays consistent."""
        a = PageAllocator(8, faults=FaultPlan.at(alloc=0))
        assert a.alloc(2) is None              # injected
        assert a.alloc_failures == 1
        assert a.alloc(2) == [1, 2]            # plan spent: back to normal
        assert a.n_free == 5


# --------------------------------------------------------- RecoveryManager
def _sched(**kw):
    pcfg = PagedCacheConfig(page_size=8, n_pages=9, max_slots=2,
                            max_blocks=4, segment_len=4)
    return ContinuousBatchingScheduler(pcfg, **kw)


def _req(rid=0, **kw):
    return Request(rid=rid, prompt=np.arange(8, dtype=np.int32),
                   max_new_tokens=4, **kw)


class TestRecoveryManager:
    def test_backoff_is_exponential_and_capped(self):
        rec = RecoveryManager(RecoveryPolicy(backoff_segments=2,
                                             backoff_factor=2.0,
                                             max_backoff_segments=12),
                              _sched())
        req = _req()
        expect = [2, 2, 4, 8, 12, 12]          # capped
        for n, want in enumerate(expect):
            req.n_retries = n
            assert rec.backoff(req) == want

    def test_hold_release_roundtrip_lanes(self):
        """Quarantined requests rejoin through the right lane: restart
        (no image) → pending, verified image → preempted (restore)."""
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(backoff_segments=2), sched)
        restart, restore = _req(0), _req(1)
        restore.swap = SwapState(pages=[1], n_tokens=8, slot=0,
                                 host_k=np.zeros(1), host_v=np.zeros(1))
        assert rec.hold(restart, "x", boundary=1, now=0.0)
        assert rec.hold(restore, "x", boundary=1, now=0.0)
        assert rec.restarts == 1               # only the image-less one
        assert rec.release_due(2) == 0         # backoff not expired
        assert rec.release_due(3) == 2
        st = sched.rm.state(restart.tenant)
        assert list(st.pending) == [restart]
        assert list(st.preempted) == [restore]
        assert not rec.has_quarantined

    def test_retry_exhaustion_dead_letters(self):
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(max_retries=1), sched)
        req = _req()
        assert rec.hold(req, "fault", boundary=1, now=0.0)
        rec._quarantine.clear()
        assert not rec.hold(req, "fault", boundary=2, now=1.0)
        assert isinstance(req.failure, RequestFailed)
        assert req.failure.retries == 2
        assert "retries exhausted" in req.failure.reason
        assert sched.rm.dead_letters == 1
        assert sched.rm.state(req.tenant).dead_lettered == 1
        assert sched.rm.stats()["dead_letters"] == 1

    def test_verify_swaps_converts_bad_images_to_restarts(self):
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(), sched)
        good, corrupt, lost = _req(0), _req(1), _req(2)
        for req, (k, v) in ((good, (np.ones(4), np.ones(4))),
                            (corrupt, (np.ones(4), np.ones(4))),
                            (lost, (None, None))):
            req.swap = SwapState(pages=[1], n_tokens=8, slot=0,
                                 host_k=k, host_v=v)
            req.tokens = [5]
            sched.rm.state(req.tenant).preempted.append(req)
        good.swap.checksum = image_checksum(good.swap.host_k,
                                            good.swap.host_v)
        corrupt.swap.checksum = image_checksum(corrupt.swap.host_k,
                                               corrupt.swap.host_v)
        corrupt.swap.host_k = corrupt_image(corrupt.swap.host_k)
        assert rec.verify_swaps(boundary=1, now=0.0) == 2
        assert rec.swap_faults_detected == 2
        st = sched.rm.state(good.tenant)
        assert list(st.preempted) == [good]    # verified image kept
        assert good.swap.verified
        # bad images became quarantined restarts: stripped clean
        assert corrupt.swap is None and lost.swap is None
        assert corrupt.tokens == [] and lost.tokens == []
        assert rec.has_quarantined
        # verification happens exactly once per image
        assert rec.verify_swaps(boundary=2, now=0.0) == 0

    def test_shed_stalled_dead_letters_stale_queue(self):
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(shed_after_boundaries=3),
                              sched)
        req = _req()
        sched.submit(req)
        for b in range(1, 4):
            assert rec.shed_stalled(boundary=b, now=float(b)) == 0
        assert rec.shed_stalled(boundary=4, now=4.0) == 1
        assert rec.shed == 1
        assert isinstance(req.failure, RequestFailed)
        assert "shed" in req.failure.reason
        assert not sched.has_work

    def test_shedding_disabled_by_default(self):
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(), sched)
        sched.submit(_req())
        assert rec.shed_stalled(boundary=10 ** 6, now=0.0) == 0

    def test_invariant_checker_flags_corruption(self):
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(check_invariants=True),
                              sched)
        sched.submit(_req())
        sched.plan_growth()
        (req,) = sched.try_admit()
        sched.finish_boundary([req])
        m = sched.pcfg.max_blocks
        bt = np.full((sched.pcfg.max_slots, m), 0, np.int32)
        bt[req.slot, :len(req.pages)] = req.pages
        seq = np.zeros((sched.pcfg.max_slots,), np.int32)
        seq[req.slot] = req.prompt_len
        bad, glob = rec.check_invariants(bt, seq)
        assert bad == [] and glob == []        # healthy state is quiet
        bt[req.slot, 0] += 1                   # corrupt the block table
        bad, _ = rec.check_invariants(bt, seq)
        assert [r.rid for r, _why in bad] == [req.rid]
        assert rec.invariant_violations

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            RecoveryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RecoveryPolicy(backoff_factor=0.5)
        with pytest.raises(ValueError):
            RecoveryPolicy(shed_after_boundaries=0)

    def test_diagnostic_snapshot_shape(self):
        import json
        sched = _sched()
        rec = RecoveryManager(RecoveryPolicy(), sched)
        req = _req()
        rec.hold(req, "x", boundary=3, now=0.0)
        snap = diagnostic_snapshot(sched, rec, boundary=3, no_progress=7)
        assert snap["boundary"] == 3 and snap["no_progress"] == 7
        assert snap["quarantined"][0]["rid"] == req.rid
        assert "free_pages" in snap and "queues" in snap
        json.dumps(snap)                       # structured == serializable


# -------------------------------------------------- engine chaos (integration)
_ENG = {}


def _engine():
    if not _ENG:
        from repro.configs.registry import get_config
        from repro.models.api import build_model
        from repro.serving import PagedServingEngine
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        pcfg = PagedCacheConfig(page_size=8, n_pages=7, max_slots=2,
                                max_blocks=4, segment_len=4)
        _ENG["x"] = (cfg, model.init(jax.random.PRNGKey(0)),
                     PagedServingEngine(model, pcfg))
    return _ENG["x"]


def _mk_reqs(cfg, n=3):
    from repro.data.synthetic import lm_tokens
    return [Request(rid=i, prompt=np.asarray(
                lm_tokens(16, cfg.vocab_size, seed=40 + i)
            ).astype(np.int32), max_new_tokens=8) for i in range(n)]


def _baseline(cfg, params, eng):
    if "base" not in _ENG:
        reqs = _mk_reqs(cfg)
        eng.run(reqs, params)
        _ENG["base"] = {r.rid: list(r.tokens) for r in reqs}
    return _ENG["base"]


@pytest.mark.parametrize("site", ENGINE_SITES)
def test_engine_recovers_bit_identical(site):
    """A fault injected at every engine-level site in the stack: run()
    never raises, every request completes, and the tokens equal the
    fault-free run.  (Replica-level sites have no opportunities inside a
    single engine run — tests/test_cluster.py covers them.)"""
    cfg, params, eng = _engine()
    base = _baseline(cfg, params, eng)
    reqs = _mk_reqs(cfg)
    out = eng.run(reqs, params, faults=FaultPlan.at(**{site: 0}))
    assert out["n_finished"] == len(reqs)
    assert out["n_dead_lettered"] == 0
    assert {r.rid: list(r.tokens) for r in reqs} == base
    assert out["faults"]["fired"] == [[site, 0]]


def test_engine_seeded_chaos_bit_identical():
    cfg, params, eng = _engine()
    base = _baseline(cfg, params, eng)
    plan = FaultPlan.seeded(0, rate=0.3, max_fires=2)
    reqs = _mk_reqs(cfg)
    out = eng.run(reqs, params, faults=plan)
    assert plan.total_fires > 0                # the chaos actually ran
    assert out["n_finished"] == len(reqs)
    assert {r.rid: list(r.tokens) for r in reqs} == base


def test_engine_dead_letters_on_retry_exhaustion():
    """With zero retries allowed, a faulted request lands dead-lettered
    (typed terminal state, per-tenant accounting) while the healthy
    requests still finish bit-identical."""
    cfg, params, eng = _engine()
    base = _baseline(cfg, params, eng)
    reqs = _mk_reqs(cfg)
    out = eng.run(reqs, params, faults=FaultPlan.at(dispatch_admit=0),
                  recovery=RecoveryPolicy(max_retries=0))
    # a faulted admit fails every request in its dispatch wave (later
    # dispatches may alias its pages), so >= 1 dead-letters here
    dead = [r for r in reqs if r.failure is not None]
    assert dead and out["n_dead_lettered"] == len(dead)
    assert all(isinstance(r.failure, RequestFailed) for r in dead)
    assert out["n_finished"] == len(reqs) - len(dead)
    assert out["recovery"]["dead_lettered"] == len(dead)
    for r in reqs:
        if r.failure is None:
            assert list(r.tokens) == base[r.rid]


def test_engine_multi_tenant_chaos_sweep():
    """Fixed-seed miniature of the hypothesis chaos property
    (tests/test_property.py) that always runs, hypothesis installed or
    not: random fault plans over multi-tenant interleavings terminate
    with every request bit-identical-or-dead-lettered and the pool
    drained (no leaked pages)."""
    from repro.configs.registry import get_config
    from repro.models.api import build_model
    from repro.serving import PagedServingEngine, TenantConfig
    cfg = get_config("qwen2_7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pcfg = PagedCacheConfig(page_size=8, n_pages=7, max_slots=2,
                            max_blocks=4, segment_len=4)
    eng = PagedServingEngine(model, pcfg,
                             tenants=[TenantConfig("a"), TenantConfig("b"),
                                      TenantConfig("c", weight=2.0)])
    cases = [(0, [8, 3, 6], ["a", "b", "c"]),
             (1, [2, 10, 5], ["c", "c", "a"]),
             (2, [7, 7], ["b", "a"])]
    from repro.data.synthetic import lm_tokens
    for fault_seed, gens, tenants in cases:
        prompts = [np.asarray(lm_tokens(16, cfg.vocab_size, seed=40 + i)
                              ).astype(np.int32) for i in range(len(gens))]
        mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                              max_new_tokens=g, tenant=t)
                      for i, (g, t) in enumerate(zip(gens, tenants))]
        base = mk()
        eng.run(base, params)
        want = {r.rid: r.tokens for r in base}
        chaos = mk()
        plan = FaultPlan.seeded(fault_seed, rate=0.2, max_fires=2)
        out = eng.run(chaos, params, faults=plan)
        for r in chaos:
            if r.failure is not None:
                assert isinstance(r.failure, RequestFailed)
            else:
                assert r.tokens == want[r.rid], \
                    f"rid {r.rid} diverged after faults {plan.log}"
        assert out["n_finished"] + out["n_dead_lettered"] == len(gens)
        assert out["free_pages"] + out["pinned_pages"] \
            == pcfg.allocatable_pages
        assert out["held_pages"] == out["pinned_pages"]


def test_engine_watchdog_raises_typed_with_snapshot():
    """A fault pattern that blocks all progress trips the watchdog: a
    typed EngineStalledError carrying the diagnostic snapshot — the only
    exception that escapes run()."""
    cfg, params, eng = _engine()
    plan = FaultPlan([FaultSpec(site="dispatch_admit", rate=1.0,
                                max_fires=200)])
    policy = RecoveryPolicy(max_retries=200, backoff_segments=0,
                            watchdog_boundaries=5)
    with pytest.raises(EngineStalledError) as ei:
        eng.run(_mk_reqs(cfg, n=1), params, faults=plan, recovery=policy)
    snap = ei.value.snapshot
    assert snap["no_progress"] > 5
    assert "queues" in snap and "recovery" in snap
    assert snap["recovery"]["quarantines"] > 0
