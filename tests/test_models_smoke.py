"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + no NaNs; decode-vs-full
consistency in fp32 (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.models.api import build_model
from repro.optim.optimizers import adamw
from repro.runtime.train_loop import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_frames, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    optimizer = adamw(1e-3)
    state = init_train_state(model, optimizer, KEY)
    step = jax.jit(make_train_step(model, optimizer))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    # params changed and stayed finite
    leaves_old = jax.tree.leaves(state["params"])
    leaves_new = jax.tree.leaves(new_state["params"])
    assert any(float(jnp.max(jnp.abs(a - b))) > 0
               for a, b in zip(leaves_old, leaves_new))
    assert all(bool(jnp.all(jnp.isfinite(b))) for b in leaves_new
               if b.dtype.kind == "f")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True).replace(act_dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, cfg.n_frames, cfg.d_model)) * 0.1
        batch["frames"] = frames
        enc = T.encdec_encode(model.ctx(), cfg, params, frames)
        full, _ = T.encdec_decode(model.ctx(), cfg, params, toks,
                                  enc_out=enc)
    else:
        full, _ = T.lm_apply(model.ctx(), cfg, params, toks)
    cache, _ = model.init_cache(b, 32, dtype=jnp.float32)
    _, cache = model.prefill(params, {**batch, "tokens": toks[:, :s - 1]},
                             cache=cache)
    dl, cache = model.decode_step(params, cache, toks[:, s - 1:s])
    err = float(jnp.max(jnp.abs(dl[:, 0] - full[:, s - 1])))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert err / scale < 2e-4, f"{arch}: decode mismatch {err} vs {scale}"


@pytest.mark.parametrize("arch", ["xlstm_125m", "zamba2_2p7b",
                                  "h2o_danube_3_4b"])
def test_multistep_decode_consistency(arch):
    """Sub-quadratic archs (the long_500k set): 4 decode steps == full."""
    cfg = get_config(arch, smoke=True).replace(act_dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    b, s, tail = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full, _ = T.lm_apply(model.ctx(), cfg, params, toks)
    cache, _ = model.init_cache(b, 32, dtype=jnp.float32)
    _, cache = model.prefill(params, {"tokens": toks[:, :s - tail]},
                             cache=cache)
    for t in range(s - tail, s):
        dl, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        err = float(jnp.max(jnp.abs(dl[:, 0] - full[:, t])))
        assert err < 2e-3, f"{arch} step {t}: {err}"


def test_sliding_window_ring_buffer_decode():
    """Danube SWA: decode beyond the window uses the ring buffer."""
    cfg = get_config("h2o_danube_3_4b", smoke=True).replace(
        act_dtype="float32", sliding_window=8)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    full, _ = T.lm_apply(model.ctx(), cfg, params, toks)
    # cache capped at window size: (layers, batch, window, kv, hd)
    cache, _ = model.init_cache(b, s, dtype=jnp.float32)
    assert cache["k"].shape[2] == 8  # cache_len == window
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache=cache)
    for t in range(8, s):
        dl, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        err = float(jnp.max(jnp.abs(dl[:, 0] - full[:, t])))
        assert err < 2e-3, f"SWA decode step {t}: err={err}"


def test_vlm_chameleon_accepts_fused_tokens():
    cfg = get_config("chameleon_34b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    logits, _ = T.lm_apply(model.ctx(), cfg, params, toks)
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_input_specs_cover_shapes():
    from repro.configs.base import SHAPES
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for shape in SHAPES.values():
            specs = model.input_specs(shape)
            assert "tokens" in specs
            if shape.kind == "train":
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
            if shape.is_decode:
                assert specs["tokens"].shape == (shape.global_batch, 1)
