import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep tests on the single real CPU device (the 512-device placeholder is
# exclusively for launch/dryrun.py, per the dry-run contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
