"""Quantized-policy TRAINING correctness (the §Perf pair-A bug class):
int8 forward paths must carry straight-through gradients, not the zero
derivative of round()."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Ctx, linear
from repro.quant.policy import PrecisionPolicy

KEY = jax.random.PRNGKey(0)


def _grad_norm(policy):
    w = jax.random.normal(KEY, (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    ctx = Ctx(policy=policy)

    def loss(w):
        return jnp.sum(linear(ctx, "mlp/w", x, w) ** 2)

    return jax.grad(loss)(w)


def test_int8_linear_has_straight_through_grads():
    g8 = _grad_norm(PrecisionPolicy(default="int8"))
    gb = _grad_norm(PrecisionPolicy(default="bf16"))
    n8 = float(jnp.linalg.norm(g8))
    nb = float(jnp.linalg.norm(gb))
    assert n8 > 0.5 * nb, "int8 path lost its gradients (round deriv=0)"
    rel = float(jnp.linalg.norm(g8 - gb)) / nb
    assert rel < 0.05, f"STE grads diverge from full precision: {rel}"


def test_int8_forward_is_actually_quantized():
    """The forward must differ from bf16 by quantization noise (i.e. the
    STE didn't silently fall back to a full-precision matmul)."""
    w = jax.random.normal(KEY, (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y8 = linear(Ctx(policy=PrecisionPolicy(default="int8")), "m", x, w)
    yf = x @ w
    diff = float(jnp.max(jnp.abs(y8 - yf)))
    assert 1e-4 < diff < 0.5, f"quantization noise out of range: {diff}"


def test_int8_expert_ffn_trains():
    """MoE expert FFN under an int8 policy: nonzero expert-weight grads."""
    from repro.configs.registry import get_config
    from repro.models import layers as L
    cfg = get_config("granite_moe_1b_a400m", smoke=True)
    p, _ = L.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    ctx = Ctx(policy=PrecisionPolicy(default="int8"))

    def loss(p):
        recv = jnp.broadcast_to(
            x.reshape(-1, cfg.d_model)[: cfg.n_experts * 2].reshape(
                cfg.n_experts, 2, cfg.d_model),
            (cfg.n_experts, 2, cfg.d_model))
        y = L._expert_ffn(ctx, recv, p["w_gate"], p["w_up"], p["w_down"])
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    gn = float(jnp.linalg.norm(g["w_gate"].reshape(-1)))
    assert gn > 1e-3, "expert FFN int8 path lost gradients"


def test_train_step_with_int8_policy_updates_params():
    from repro.configs.registry import get_config
    from repro.models.api import build_model
    from repro.optim.optimizers import adamw
    from repro.runtime.train_loop import init_train_state, make_train_step
    cfg = get_config("qwen2_7b", smoke=True)
    policy = PrecisionPolicy(default="bf16").with_rule("*mlp*", "int8")
    model = build_model(cfg, policy=policy)
    opt = adamw(1e-3)
    state = init_train_state(model, opt, KEY)
    step = jax.jit(make_train_step(model, opt))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    new_state, metrics = step(state, {"tokens": toks,
                                      "labels": jnp.roll(toks, -1, 1)})
    # the int8-quantized mlp weights must still receive updates
    w_old = state["params"]["blocks"]["mlp"]["w_up"]
    w_new = new_state["params"]["blocks"]["mlp"]["w_up"]
    delta = float(jnp.max(jnp.abs(w_new - w_old)))
    assert delta > 0, "int8-policy mlp weights frozen"
    assert np.isfinite(float(metrics["loss"]))
