"""Paged KV-cache + continuous batching: allocator, kernel-vs-oracle,
paged-vs-contiguous token equality, page reuse, scheduler admit/evict,
prefix-sharing/CoW, the batched ragged admission prefill, and the
quota-aware resource manager (growth-on-demand paging, host-swap
preemption/restore, multi-tenant budgets + DRR, prefix retention)."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.data.synthetic import lm_tokens
from repro.kernels.flash_decode_paged import flash_decode_paged
from repro.kernels.flash_prefill_ragged import flash_prefill_ragged
from repro.launch.serve import generate, make_serve_fns
from repro.models import layers as L
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingScheduler, PageAllocator,
                           PagedCacheConfig, PagedServingEngine,
                           PrefixCache, Request, TenantConfig,
                           TRASH_PAGE, init_paged_cache)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- allocator
class TestPageAllocator:
    def test_alloc_free_reuse(self):
        a = PageAllocator(8)                       # pages 1..7 allocatable
        p1 = a.alloc(3)
        assert p1 == [1, 2, 3]
        assert a.n_free == 4
        a.release(p1)
        assert a.n_free == 7
        # freed pages are reused first, lowest-first
        assert a.alloc(2) == [1, 2]

    def test_never_hands_out_trash_page(self):
        a = PageAllocator(4)
        assert TRASH_PAGE not in a.alloc(3)

    def test_all_or_nothing(self):
        a = PageAllocator(4)
        assert a.alloc(3) is not None
        assert a.alloc(1) is None                  # exhausted
        assert a.n_free == 0

    def test_double_free_rejected(self):
        a = PageAllocator(4)
        p = a.alloc(2)
        a.release(p)
        with pytest.raises(ValueError):
            a.release(p)

    def test_capacity_validation(self):
        pcfg = PagedCacheConfig(page_size=4, n_pages=8, max_slots=2,
                                max_blocks=2)
        with pytest.raises(ValueError):
            pcfg.validate_request(prompt_len=8, max_new_tokens=4)
        assert pcfg.validate_request(prompt_len=4, max_new_tokens=3) == 2


# ------------------------------------------------- position/mask helpers
class TestPagedMaskHelpers:
    def test_matches_contiguous_helpers(self):
        """Per-request paged positions/mask rows must equal the linear
        contiguous-cache helpers at the same position."""
        n_slots = 24
        seq_lens = jnp.asarray([0, 5, 23], jnp.int32)
        kv_pos = L.paged_kv_positions(seq_lens, n_slots)
        mask = L.paged_decode_attention_mask(kv_pos, seq_lens)
        for i, pos in enumerate([0, 5, 23]):
            ref_pos = L.kv_positions_for_cache(jnp.asarray(pos), n_slots, 0)
            ref_mask = L.decode_attention_mask(ref_pos, pos, 0)
            assert bool(jnp.all(kv_pos[i] == ref_pos))
            assert bool(jnp.all(mask[i] == ref_mask))

    def test_ragged_rows(self):
        seq_lens = jnp.asarray([2, 7], jnp.int32)
        mask = L.paged_decode_attention_mask(
            L.paged_kv_positions(seq_lens, 8), seq_lens)
        assert mask.astype(int).sum(axis=1).tolist() == [3, 8]


# ------------------------------------------------------ kernel vs oracle
def _paged_problem(key, slots, h, kvh, d, page_size, blocks, seq_lens):
    """Random pages + a scrambled block table + the shared mask."""
    ks = jax.random.split(key, 4)
    n_pages = slots * blocks + 1
    q = jax.random.normal(ks[0], (slots, 1, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, page_size, kvh, d),
                           jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, page_size, kvh, d),
                           jnp.float32)
    perm = jax.random.permutation(ks[3], n_pages - 1) + 1
    bt = perm[:slots * blocks].reshape(slots, blocks).astype(jnp.int32)
    sl = jnp.asarray(seq_lens, jnp.int32)
    mask = L.paged_decode_attention_mask(
        L.paged_kv_positions(sl, blocks * page_size), sl)
    return q, kp, vp, bt, mask


def _oracle(q, kp, vp, bt, mask):
    slots, _, h, d = q.shape
    _, ps, kvh, _ = kp.shape
    blocks = bt.shape[1]
    kf = kp[bt].reshape(slots, blocks * ps, kvh, d)
    vf = vp[bt].reshape(slots, blocks * ps, kvh, d)
    k_exp = L._expand_kv(kf, h)
    v_exp = L._expand_kv(vf, h)
    s = jnp.einsum("bqhd,bkhd->bhqk",
                   q.astype(jnp.float32) / math.sqrt(d),
                   k_exp.astype(jnp.float32))
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v_exp.astype(jnp.float32))


class TestPagedKernelVsOracle:
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (7, 1)])
    @pytest.mark.parametrize("page_size", [4, 8, 16])
    def test_gqa_and_page_size_grid(self, h, kvh, page_size):
        slots, blocks, d = 3, 3, 8
        cap = blocks * page_size
        seq_lens = [0, cap // 2, cap - 1]          # empty-ish / mid / full
        q, kp, vp, bt, mask = _paged_problem(
            KEY, slots, h, kvh, d, page_size, blocks, seq_lens)
        out = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        ref = _oracle(q, kp, vp, bt, mask)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    @pytest.mark.parametrize("seq_lens", [[0, 1, 2, 3], [5, 17, 9, 30]])
    def test_ragged_lengths(self, seq_lens):
        slots, blocks, ps, h, kvh, d = 4, 4, 8, 4, 2, 8
        q, kp, vp, bt, mask = _paged_problem(
            jax.random.PRNGKey(7), slots, h, kvh, d, ps, blocks, seq_lens)
        out = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        ref = _oracle(q, kp, vp, bt, mask)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    def test_scrambled_vs_identity_block_table(self):
        """Physical page placement must be invisible: the same logical
        K/V through a scrambled table equals an identity layout."""
        slots, blocks, ps, h, kvh, d = 2, 3, 4, 4, 2, 8
        seq_lens = [7, 11]
        q, kp, vp, bt, mask = _paged_problem(
            jax.random.PRNGKey(3), slots, h, kvh, d, ps, blocks, seq_lens)
        ident_bt = 1 + jnp.arange(slots * blocks,
                                  dtype=jnp.int32).reshape(slots, blocks)
        kp_i = kp.at[ident_bt.reshape(-1)].set(kp[bt.reshape(-1)])
        vp_i = vp.at[ident_bt.reshape(-1)].set(vp[bt.reshape(-1)])
        out_s = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        out_i = flash_decode_paged(q, kp_i, vp_i, ident_bt, mask,
                                   interpret=True)
        assert float(jnp.max(jnp.abs(out_s - out_i))) < 1e-6


# ------------------------------------- engine: paged vs contiguous tokens
def _smoke_setup():
    cfg = get_config("qwen2_7b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _prompts(cfg, n, prompt_len, seed=1):
    return np.asarray(lm_tokens(n * prompt_len, cfg.vocab_size, seed=seed)
                      ).reshape(n, prompt_len).astype(np.int32)


def _contiguous_tokens(model, params, prompts, gen):
    fns = make_serve_fns(model)
    out = {}
    for i in range(prompts.shape[0]):
        toks = generate(model, params, jnp.asarray(prompts[i:i + 1]), gen,
                        prompts.shape[1] + gen + 1, scan=True, fns=fns)
        out[i] = [int(t) for t in np.asarray(toks)[0]]
    return out


class TestPagedEngineTokens:
    @pytest.mark.parametrize("page_size", [8, 16, 32])
    def test_tokens_equal_contiguous_across_page_sizes(self, page_size):
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 9, 3
        prompts = _prompts(cfg, n, prompt_len)
        base = _contiguous_tokens(model, params, prompts, gen)
        blocks = -(-(prompt_len + gen + 1) // page_size)
        pcfg = PagedCacheConfig(page_size=page_size,
                                n_pages=2 * blocks * 2 + 1,
                                max_slots=2, max_blocks=blocks,
                                segment_len=4)
        eng = PagedServingEngine(model, pcfg)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        eng.run(reqs, params)
        for r in reqs:
            assert r.tokens == base[r.rid], (page_size, r.rid)

    def test_kernel_path_tokens_equal_oracle_path(self):
        cfg, model, params = _smoke_setup()
        model_k = build_model(cfg, use_kernels=True, interpret=True)
        prompt_len, gen, n = 16, 8, 3
        prompts = _prompts(cfg, n, prompt_len, seed=5)
        pcfg = PagedCacheConfig(page_size=8, n_pages=16, max_slots=2,
                                max_blocks=4, segment_len=4)
        res = {}
        for name, mdl in (("oracle", model), ("kernel", model_k)):
            reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                    for i in range(n)]
            PagedServingEngine(mdl, pcfg).run(reqs, params)
            res[name] = {r.rid: r.tokens for r in reqs}
        assert res["oracle"] == res["kernel"]

    def test_ragged_max_new_tokens(self):
        """Requests finishing at different steps: each still matches its
        own contiguous reference."""
        cfg, model, params = _smoke_setup()
        prompt_len = 16
        gens = [3, 11, 7, 5]
        prompts = _prompts(cfg, len(gens), prompt_len, seed=9)
        fns = make_serve_fns(model)
        base = {}
        for i, g in enumerate(gens):
            toks = generate(model, params, jnp.asarray(prompts[i:i + 1]),
                            g, prompt_len + g + 1, scan=True, fns=fns)
            base[i] = [int(t) for t in np.asarray(toks)[0]]
        pcfg = PagedCacheConfig(page_size=8, n_pages=16, max_slots=3,
                                max_blocks=4, segment_len=4)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=g)
                for i, g in enumerate(gens)]
        PagedServingEngine(model, pcfg).run(reqs, params)
        for r in reqs:
            assert len(r.tokens) == gens[r.rid]
            assert r.tokens == base[r.rid]

    def test_page_reuse_after_completion(self):
        """A pool sized for ~one request at a time forces later requests
        onto recycled pages; tokens must stay correct."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 6, 4
        prompts = _prompts(cfg, n, prompt_len, seed=3)
        base = _contiguous_tokens(model, params, prompts, gen)
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=2,
                                max_blocks=3, segment_len=2)
        # pages_for(16+6+1)=3 = entire allocatable pool: strictly serial
        # admission, every admission after the first reuses freed pages
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        eng = PagedServingEngine(model, pcfg)
        eng.run(reqs, params)
        for r in reqs:
            assert r.tokens == base[r.rid]


# -------------------------------------------------------------- scheduler
class TestScheduler:
    def test_admit_evict_across_segments(self):
        """More requests than slots: admissions must be spread over the
        run (continuous batching), not all up front, and every request
        completes with freed pages accounted for."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 6, 5
        prompts = _prompts(cfg, n, prompt_len, seed=11)
        pcfg = PagedCacheConfig(page_size=8, n_pages=8, max_slots=2,
                                max_blocks=3, segment_len=2)
        eng = PagedServingEngine(model, pcfg)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        stats = eng.run(reqs, params)
        assert stats["n_finished"] == n
        assert all(len(r.tokens) == gen for r in reqs)
        # 5 requests through 2 slots cannot be co-resident: admissions
        # must span multiple scheduler syncs
        admit_times = sorted(r.t_admitted for r in reqs)
        done_times = sorted(r.t_done for r in reqs)
        assert admit_times[-1] > done_times[0]

    def test_admission_blocks_on_pages_not_just_slots(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=4,
                                max_blocks=3)
        sched = ContinuousBatchingScheduler(pcfg)
        for i in range(3):
            sched.submit(Request(rid=i, prompt=np.zeros(8, np.int32),
                                 max_new_tokens=8))
        admitted = sched.try_admit()
        # each request needs pages_for(8+8+1)=3 pages; pool has 3 free
        assert len(admitted) == 1
        assert sched.pending and sched.free_slots
        sched.complete(admitted[0].slot)
        assert len(sched.try_admit()) == 1

    def test_fifo_no_overtaking(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=4,
                                max_blocks=3)
        sched = ContinuousBatchingScheduler(pcfg)
        big = Request(rid="big", prompt=np.zeros(16, np.int32),
                      max_new_tokens=7)
        small = Request(rid="small", prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)
        filler = Request(rid="filler", prompt=np.zeros(8, np.int32),
                         max_new_tokens=6)
        sched.submit(filler)
        assert [r.rid for r in sched.try_admit()] == ["filler"]  # 2 pages
        sched.submit(big)      # needs 3 pages, only 1 free
        sched.submit(small)    # would fit, but must not overtake big
        assert sched.try_admit() == []

    def test_trash_page_never_allocated(self):
        cfg, _, _ = _smoke_setup()
        pcfg = PagedCacheConfig(page_size=8, n_pages=6, max_slots=2,
                                max_blocks=3)
        cache, _ = init_paged_cache(cfg, pcfg)
        assert bool(jnp.all(cache["block_tables"] == TRASH_PAGE))
        sched = ContinuousBatchingScheduler(pcfg)
        sched.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                             max_new_tokens=8))
        (req,) = sched.try_admit()
        assert TRASH_PAGE not in req.pages

    def test_paging_gated_families(self):
        from repro.serving.paged_cache import supports_paging
        assert supports_paging(get_config("qwen2_7b", smoke=True))
        assert not supports_paging(
            get_config("h2o_danube_3_4b", smoke=True))   # sliding window
        assert not supports_paging(
            get_config("zamba2_2p7b", smoke=True))       # hybrid SSM
        with pytest.raises(ValueError):
            PagedServingEngine(
                build_model(get_config("h2o_danube_3_4b", smoke=True)),
                PagedCacheConfig())


# ------------------------------------------------------ autotune problem
class TestPagedAutotune:
    def test_registered_and_tunable(self, tmp_path):
        from repro.kernels import autotune
        prob = autotune.flash_decode_paged_problem(2, 4, 2, 8, 16,
                                                   "float32")
        cands = autotune.enumerate_candidates("flash_decode_paged", prob)
        assert {"page_size": 16} in [c for c, _ in cands]  # default
        res = autotune.tune("flash_decode_paged", prob,
                            cache_path=str(tmp_path / "c.json"), iters=1)
        assert res.config["page_size"] >= 1
        again = autotune.tune("flash_decode_paged", prob,
                              cache_path=str(tmp_path / "c.json"),
                              iters=1)
        assert again.cached and again.config == res.config

    def test_tune_task_derives_paged_problem(self):
        from repro.tasks.tune import derive_problems
        from repro.tasks.handle import DNNHandle
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        params = model.init(KEY)
        handle = DNNHandle(kind="lm", name="m", params=params,
                           model=model)
        probs = derive_problems(handle, max_problems=16)
        kernels = [p["kernel"] for p in probs]
        assert "flash_decode_paged" in kernels
        # windowed arch: ring-buffer cache is not paged -> no paged problem
        wcfg = get_config("h2o_danube_3_4b", smoke=True)
        wmodel = build_model(wcfg)
        whandle = DNNHandle(kind="lm", name="w", params=wmodel.init(KEY),
                            model=wmodel)
        wkernels = [p["kernel"]
                    for p in derive_problems(whandle, max_problems=16)]
        assert "flash_decode_paged" not in wkernels


# ----------------------------------------------- refcounts + prefix trie
class TestRefcountedAllocator:
    def test_share_release_lifecycle(self):
        a = PageAllocator(8)
        pages = a.alloc(3)
        a.share(pages[:2])                      # map into a second request
        assert a.refcount(pages[0]) == 2 and a.is_shared(pages[0])
        assert a.release(pages) == [pages[2]]   # only the unshared frees
        assert a.n_free == 5
        assert a.release(pages[:2]) == pages[:2]
        assert a.n_free == 7

    def test_share_free_page_rejected(self):
        a = PageAllocator(4)
        p = a.alloc(1)
        a.release(p)
        with pytest.raises(ValueError):
            a.share(p)

    def test_generation_bumps_on_realloc(self):
        a = PageAllocator(4)
        p = a.alloc(1)[0]
        g0 = a.generation(p)
        a.release([p])
        assert a.alloc(1) == [p]                # freed-first reuse
        assert a.generation(p) == g0 + 1


def _trie(n_pages=32, ps=8, chunk_pages=1):
    alloc = PageAllocator(n_pages)
    return alloc, PrefixCache(alloc, ps, chunk_pages=chunk_pages)


class TestPrefixCache:
    def test_full_chunk_match_and_always_leaves_suffix(self):
        alloc, pc = _trie()
        toks = np.arange(24, dtype=np.int32)
        pages = alloc.alloc(3)
        pc.insert(toks, 24, pages)
        pc.mark_ready()
        m = pc.lookup(toks)
        # 24 tokens = 3 aligned pages, but the last token must stay
        # unmatched (the admission still needs first-token logits), so
        # only the first 2 full pages are shareable
        assert list(m.pages) == pages[:2]
        assert m.n_tokens == 16 and m.tail_src is None

    def test_divergent_prompt_partial_match(self):
        alloc, pc = _trie()
        toks = np.arange(24, dtype=np.int32)
        pages = alloc.alloc(3)
        pc.insert(toks, 24, pages)
        pc.mark_ready()
        other = toks.copy()
        other[12] += 1                          # diverge inside page 2
        m = pc.lookup(other)
        assert list(m.pages) == pages[:1] and m.n_tokens == 8

    def test_tail_cow_match_requires_ready(self):
        alloc, pc = _trie()
        toks = np.arange(13, dtype=np.int32)
        pages = alloc.alloc(2)
        pc.insert(toks, 13, pages)
        m = pc.lookup(toks)                     # same boundary: not ready
        assert m.tail_src is None and m.n_tokens == 8
        pc.mark_ready()
        m = pc.lookup(toks)
        assert m.tail_src == pages[1]
        assert m.tail_tokens == 4               # 13 - 8 capped at len-1
        assert m.n_tokens == 12

    def test_entries_invalidate_after_free_and_realloc(self):
        alloc, pc = _trie(n_pages=4)
        toks = np.arange(16, dtype=np.int32)
        pages = alloc.alloc(2)
        pc.insert(toks, 16, pages)
        pc.mark_ready()
        alloc.release(pages)                    # owner completes
        assert pc.lookup(toks).n_tokens == 0    # refcount-0 page: stale
        other = np.arange(100, 116, dtype=np.int32)
        p2 = alloc.alloc(2)                     # same ids, new generation
        assert p2 == pages
        pc.insert(other, 16, p2)
        pc.mark_ready()
        assert pc.lookup(toks).n_tokens == 0    # old tokens never match
        assert pc.lookup(other).n_tokens == 8

    def test_chunk_pages_granularity(self):
        alloc, pc = _trie(ps=4, chunk_pages=2)  # 8-token match granule
        toks = np.arange(20, dtype=np.int32)
        pages = alloc.alloc(5)
        pc.insert(toks, 20, pages)
        pc.mark_ready()
        m = pc.lookup(toks)
        # two full 8-token chunks cover 4 pages; the 4-token tail page
        # is a CoW candidate at page granularity
        assert list(m.pages) == pages[:4]
        assert m.tail_src == pages[4] and m.n_tokens == 19


class TestPagedCacheConfigRoundTrip:
    def test_to_from_dict_roundtrip(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=17, max_slots=3,
                                max_blocks=5, segment_len=4,
                                enable_prefix_sharing=False,
                                prefix_chunk_pages=2, prefill_bucket=4)
        d = pcfg.to_dict()
        assert PagedCacheConfig.from_dict(d) == pcfg
        assert d["enable_prefix_sharing"] is False

    def test_from_dict_tolerates_old_and_future_configs(self):
        # a config persisted before the prefix-sharing knobs existed
        old = {"page_size": 8, "n_pages": 16, "max_slots": 2,
               "max_blocks": 4, "segment_len": 8}
        pcfg = PagedCacheConfig.from_dict(old)
        assert pcfg.enable_prefix_sharing          # default applies
        # and one persisted by a future version with an unknown knob
        fut = dict(old, some_future_knob=123)
        assert PagedCacheConfig.from_dict(fut).page_size == 8

    def test_checkpoint_extra_roundtrip(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        pcfg = PagedCacheConfig(page_size=8, prefix_chunk_pages=2)
        mgr.save(1, {"w": jnp.zeros((2,))},
                 extra={"paged_cache": pcfg.to_dict()})
        _, meta = mgr.restore()
        assert PagedCacheConfig.from_dict(
            meta["extra"]["paged_cache"]) == pcfg


# ------------------------------------------- ragged prefill kernel/oracle
def _ragged_problem(key, slots, s, h, kvh, d, ps, blocks, offs, lens):
    ks = jax.random.split(key, 3)
    n_pages = slots * blocks + 1
    q = jax.random.normal(ks[0], (slots, s, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, ps, kvh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, ps, kvh, d), jnp.float32)
    perm = jax.random.permutation(ks[2], n_pages - 1) + 1
    bt = perm[:slots * blocks].reshape(slots, blocks).astype(jnp.int32)
    return (q, kp, vp, bt, jnp.asarray(offs, jnp.int32),
            jnp.asarray(lens, jnp.int32))


def _ragged_oracle(q, kp, vp, bt, offs, lens):
    """Direct masked softmax over the shared mask helper — independent of
    both the kernel and the mea-based layer oracle."""
    r, s, h, d = q.shape
    _, ps, kvh, _ = kp.shape
    n = bt.shape[1] * ps
    kf = L._expand_kv(kp[bt].reshape(r, n, kvh, d), h)
    vf = L._expand_kv(vp[bt].reshape(r, n, kvh, d), h)
    mask = L.ragged_prefill_attention_mask(offs, lens, s, n)
    sgl = jnp.einsum("bqhd,bkhd->bhqk",
                     q.astype(jnp.float32) / math.sqrt(d),
                     kf.astype(jnp.float32))
    sgl = jnp.where(mask[:, None], sgl, -1e30)
    w = jax.nn.softmax(sgl, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf.astype(jnp.float32))
    valid = jnp.arange(s)[None] < lens[:, None]
    return jnp.where(valid[:, :, None, None], out, 0.0)


class TestRaggedPrefillKernel:
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (7, 1)])
    @pytest.mark.parametrize("page_size", [4, 8, 16])
    def test_gqa_and_page_size_grid(self, h, kvh, page_size):
        slots, s, blocks, d = 3, 8, 4, 8
        offs = [0, page_size, 2 * page_size]     # suffix after a prefix
        lens = [8, 5, 0]                         # full / ragged / idle
        q, kp, vp, bt, off, ln = _ragged_problem(
            KEY, slots, s, h, kvh, d, page_size, blocks, offs, lens)
        out = flash_prefill_ragged(q, kp, vp, bt, off, ln, interpret=True,
                                   block_q=4)
        ref = _ragged_oracle(q, kp, vp, bt, off, ln)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    @pytest.mark.parametrize("block_q", [2, 4, 8, 32])
    def test_block_q_grid_and_padding(self, block_q):
        """Ragged suffix lengths with q-tile padding: every tile size
        reduces to the same result (incl. bq > s, which clamps)."""
        slots, s, h, kvh, d, ps, blocks = 4, 7, 4, 2, 8, 8, 4
        offs = [0, 3, 8, 24]
        lens = [7, 4, 7, 1]
        q, kp, vp, bt, off, ln = _ragged_problem(
            jax.random.PRNGKey(3), slots, s, h, kvh, d, ps, blocks, offs,
            lens)
        out = flash_prefill_ragged(q, kp, vp, bt, off, ln, interpret=True,
                                   block_q=block_q)
        ref = _ragged_oracle(q, kp, vp, bt, off, ln)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    def test_mask_helper_is_single_source(self):
        """The kernel's in-kernel iota mask and the shared helper agree:
        flipping any single (query, slot) admissibility in the helper
        changes the oracle away from the kernel."""
        slots, s, h, kvh, d, ps, blocks = 2, 4, 2, 1, 8, 4, 3
        offs, lens = [2, 5], [4, 3]
        q, kp, vp, bt, off, ln = _ragged_problem(
            jax.random.PRNGKey(5), slots, s, h, kvh, d, ps, blocks, offs,
            lens)
        out = flash_prefill_ragged(q, kp, vp, bt, off, ln, interpret=True)
        ref = _ragged_oracle(q, kp, vp, bt, off, ln)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
        # causal frontier sanity directly on the helper
        mask = L.ragged_prefill_attention_mask(off, ln, s, blocks * ps)
        assert mask[0, 0].astype(int).sum() == offs[0] + 1
        assert mask[1, 2].astype(int).sum() == offs[1] + 3
        assert not bool(mask[1, 3].any())        # past lens: dead row


# --------------------------------------- prefix-sharing engine behavior
def _serve_setup(arch="qwen2_7b"):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _engine_tokens(model, params, pcfg, reqs_fn, mode):
    reqs = reqs_fn()
    stats = PagedServingEngine(model, pcfg, prefill_mode=mode).run(
        reqs, params)
    return {r.rid: list(r.tokens) for r in reqs}, stats


class TestPrefixSharingEngine:
    @pytest.mark.parametrize("arch", ["qwen2_7b", "starcoder2_3b"])
    @pytest.mark.parametrize("page_size", [8, 16])
    def test_burst_page_bound_and_token_equality(self, arch, page_size):
        """Acceptance: 8 requests sharing a page-aligned common prefix
        allocate no more than (unique tokens rounded up to pages) plus
        one CoW page per request, and generate tokens identical to the
        non-shared serial engine — across GQA ratios, page sizes, and
        ragged prompt lengths."""
        cfg, model, params = _serve_setup(arch)
        n, gen = 8, 4
        prefix_len = 2 * page_size              # page-aligned prefix
        suffixes = [3, 7, 1, page_size, 5, 2, 6, 4]   # ragged tails
        prefix = np.asarray(lm_tokens(prefix_len, cfg.vocab_size,
                                      seed=31)).astype(np.int32)
        prompts = [np.concatenate([
            prefix, np.asarray(lm_tokens(sfx, cfg.vocab_size,
                                         seed=40 + i)).astype(np.int32)])
            for i, sfx in enumerate(suffixes)]
        cap = prefix_len + max(suffixes) + gen + 1
        blocks = -(-cap // page_size)
        pcfg = PagedCacheConfig(page_size=page_size,
                                n_pages=n * blocks + 1, max_slots=n,
                                max_blocks=blocks, segment_len=4)
        mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                              max_new_tokens=gen) for i in range(n)]
        base, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        got, stats = _engine_tokens(model, params, pcfg, mk, "batched")
        assert got == base
        # page bound: the prefix is allocated once; each request adds at
        # most its own unique tokens rounded up to pages, plus one CoW
        # page of allowance
        pages_unique = pcfg.pages_for(prefix_len) + sum(
            pcfg.pages_for(sfx + gen + 1) for sfx in suffixes)
        assert stats["pages_allocated_total"] <= pages_unique + n
        # and the sharing actually happened: 7 of 8 admissions hit
        assert stats["prefix_hits"] == n - 1
        assert stats["pages_shared_total"] >= \
            (n - 1) * pcfg.pages_for(prefix_len)

    def test_cow_tail_fork_across_boundaries(self):
        """A later admission whose prompt extends into a running owner's
        partially-filled tail page forks it copy-on-write; tokens still
        match the non-shared engine."""
        cfg, model, params = _serve_setup()
        plen = 13                               # 1 full page + 5-token tail
        prompt = np.asarray(lm_tokens(plen, cfg.vocab_size,
                                      seed=2)).astype(np.int32)
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=2,
                                max_blocks=4, segment_len=2)
        gens = [14, 2, 5]     # owner outlives B; C admitted mid-owner
        mk = lambda: [Request(rid=i, prompt=prompt.copy(),  # noqa
                              max_new_tokens=g)
                      for i, g in enumerate(gens)]
        base, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        got, stats = _engine_tokens(model, params, pcfg, mk, "batched")
        assert got == base
        # C matched the owner's full page (8) AND its 4-token tail (the
        # 13th token always stays unmatched for first-token logits)
        assert stats["prefix_tokens_matched"] >= 8 + 12

    def test_sharing_disabled_by_config(self):
        cfg, model, params = _serve_setup()
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=4,
                                max_blocks=4, segment_len=4,
                                enable_prefix_sharing=False)
        prompt = np.asarray(lm_tokens(16, cfg.vocab_size,
                                      seed=7)).astype(np.int32)
        mk = lambda: [Request(rid=i, prompt=prompt.copy(),  # noqa
                              max_new_tokens=3) for i in range(3)]
        got, stats = _engine_tokens(model, params, pcfg, mk, "batched")
        assert stats["prefix_lookups"] == 0
        assert stats["pages_shared_total"] == 0
        base, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        assert got == base

    def test_kernel_path_tokens_equal_oracle_shared(self):
        cfg, model, params = _serve_setup()
        model_k = build_model(cfg, use_kernels=True, interpret=True)
        prefix = np.asarray(lm_tokens(16, cfg.vocab_size,
                                      seed=3)).astype(np.int32)
        prompts = [np.concatenate([
            prefix, np.asarray(lm_tokens(sfx, cfg.vocab_size,
                                         seed=50 + sfx)).astype(np.int32)])
            for sfx in (3, 7, 9)]
        prompts.append(np.asarray(lm_tokens(11, cfg.vocab_size,
                                            seed=99)).astype(np.int32))
        pcfg = PagedCacheConfig(page_size=8, n_pages=40, max_slots=4,
                                max_blocks=5, segment_len=4)
        mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                              max_new_tokens=5)
                      for i in range(len(prompts))]
        oracle, _ = _engine_tokens(model, params, pcfg, mk, "batched")
        kernel, _ = _engine_tokens(model_k, params, pcfg, mk, "batched")
        serial, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        assert oracle == serial
        assert kernel == serial


class TestBatchedPrefillBitIdentical:
    @pytest.mark.parametrize("plens", [(16, 13, 9), (8, 8, 8), (23,)])
    def test_pages_bit_identical_to_serial(self, plens):
        """Acceptance: batched ragged admission prefill writes exactly
        the same KV pages (and first tokens) as PR 3's serial batch-1
        prefill, bit for bit."""
        cfg, model, params = _serve_setup()
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=4,
                                max_blocks=4, segment_len=4)
        prompts = [np.asarray(lm_tokens(pl, cfg.vocab_size,
                                        seed=5 + i)).astype(np.int32)
                   for i, pl in enumerate(plens)]
        pools = {}
        for mode in ("serial", "batched"):
            eng = PagedServingEngine(model, pcfg, prefill_mode=mode)
            sched = ContinuousBatchingScheduler(pcfg, sharing=False)
            for i, p in enumerate(prompts):
                sched.submit(Request(rid=i, prompt=p, max_new_tokens=1))
            admitted = sched.try_admit()
            assert len(admitted) == len(prompts)
            cache, _ = init_paged_cache(cfg, pcfg, eng.cache_dtype)
            bt = np.full((pcfg.max_slots, pcfg.max_blocks), TRASH_PAGE,
                         np.int32)
            if mode == "batched":
                cache, toks, _, _ = eng._admit_batched(cache, bt,
                                                       admitted, params)
                first = [toks[r.slot] for r in admitted]
            else:
                first = []
                for req in admitted:
                    cache, t = eng._admit_serial(cache, bt, req, params)
                    first.append(t)
            pools[mode] = (np.asarray(cache["blocks"]["k_pages"]),
                           np.asarray(cache["blocks"]["v_pages"]),
                           first,
                           {r.rid: list(r.pages) for r in admitted})
        ks, vs, tok_s, pages = pools["serial"]
        kb, vb, tok_b, pages_b = pools["batched"]
        assert tok_s == tok_b
        assert pages == pages_b                  # same allocation order
        ps = pcfg.page_size
        for rid, pgs in pages.items():
            pl = len(prompts[rid])
            for bi in range(pcfg.pages_for(pl)):
                valid = min(ps, pl - bi * ps)
                pg = pgs[bi]
                assert np.array_equal(ks[:, pg, :valid],
                                      kb[:, pg, :valid]), (rid, bi)
                assert np.array_equal(vs[:, pg, :valid],
                                      vb[:, pg, :valid]), (rid, bi)


class TestRaggedPrefillAutotune:
    def test_registered_and_tunable(self, tmp_path):
        from repro.kernels import autotune
        prob = autotune.flash_prefill_ragged_problem(2, 16, 4, 2, 8, 32,
                                                     8, "float32")
        cands = autotune.enumerate_candidates("flash_prefill_ragged",
                                              prob)
        assert {"block_q": 32} in [c for c, _ in cands]   # default
        res = autotune.tune("flash_prefill_ragged", prob,
                            cache_path=str(tmp_path / "c.json"), iters=1)
        assert res.config["block_q"] >= 1
        again = autotune.tune("flash_prefill_ragged", prob,
                              cache_path=str(tmp_path / "c.json"),
                              iters=1)
        assert again.cached and again.config == res.config

    def test_tune_task_derives_ragged_prefill_problem(self):
        from repro.tasks.tune import derive_problems
        from repro.tasks.handle import DNNHandle
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        handle = DNNHandle(kind="lm", name="m",
                           params=model.init(KEY), model=model)
        probs = derive_problems(handle, max_problems=16)
        fpr = [p for p in probs if p["kernel"] == "flash_prefill_ragged"]
        assert len(fpr) == 1
        # the page size (the prefix-match granule) rides in the problem:
        # TUNE tunes the suffix tile against the pool layout it selects
        assert fpr[0]["page_size"] >= 1
        wcfg = get_config("h2o_danube_3_4b", smoke=True)   # windowed
        wmodel = build_model(wcfg)
        whandle = DNNHandle(kind="lm", name="w",
                            params=wmodel.init(KEY), model=wmodel)
        wkernels = [p["kernel"]
                    for p in derive_problems(whandle, max_problems=16)]
        assert "flash_prefill_ragged" not in wkernels


class TestAdmissionOrdering:
    def test_same_boundary_sharer_with_longer_suffix(self):
        """Regression: a sharer whose own suffix outgrows its prefix
        owner's whole suffix (short cached system prompt + long user
        message, admitted at the same boundary) must not dispatch before
        the owner has written the shared pages."""
        cfg, model, params = _serve_setup()
        owner = np.asarray(lm_tokens(8, cfg.vocab_size,
                                     seed=61)).astype(np.int32)
        long_user = np.asarray(lm_tokens(32, cfg.vocab_size,
                                         seed=62)).astype(np.int32)
        sharer = np.concatenate([owner, long_user])
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=2,
                                max_blocks=6, segment_len=4)
        mk = lambda: [Request(rid=0, prompt=owner.copy(),  # noqa
                              max_new_tokens=4),
                      Request(rid=1, prompt=sharer.copy(),
                              max_new_tokens=4)]
        base, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        got, stats = _engine_tokens(model, params, pcfg, mk, "batched")
        assert stats["prefix_tokens_matched"] == 8   # sharing did happen
        assert got == base

    def test_cow_dst_for_exactly_full_tail_page(self):
        """Regression: a matched tail that fills its page exactly
        (reachable with multi-page chunk granules) must fork into the
        page holding the last matched token, not one past it."""
        cfg, model, params = _serve_setup()
        owner_p = np.asarray(lm_tokens(20, cfg.vocab_size,
                                       seed=71)).astype(np.int32)
        sharer_p = np.concatenate([
            owner_p, np.asarray(lm_tokens(4, cfg.vocab_size,
                                          seed=72)).astype(np.int32)])
        pcfg = PagedCacheConfig(page_size=4, n_pages=40, max_slots=2,
                                max_blocks=10, segment_len=2,
                                prefix_chunk_pages=2)
        gens = [14, 2, 5]       # owner outlives filler; sharer joins late
        prompts = [owner_p, owner_p, sharer_p]
        mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                              max_new_tokens=g)
                      for i, g in enumerate(gens)]
        base, _ = _engine_tokens(model, params, pcfg, mk, "serial")
        got, stats = _engine_tokens(model, params, pcfg, mk, "batched")
        # the late sharer matched 2 full 8-token chunks + the full-page
        # 4-token tail of the running owner (20 of its 24 tokens)
        assert stats["prefix_tokens_matched"] >= 20
        assert got == base


# ----------------------------------- resource manager: growth on demand
class TestGrowthOnDemand:
    def test_admission_backs_one_segment_not_the_lifetime(self):
        """The old scheduler reserved prompt+max_new+1 at admission; the
        resource manager backs only prompt + one segment and grows the
        rest on demand."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=4,
                                max_blocks=5, segment_len=4)
        sched = ContinuousBatchingScheduler(pcfg)
        sched.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                             max_new_tokens=24))
        (req,) = sched.try_admit()
        # coverage: min(8 + 4 + 1, 8 + 24 + 1) = 13 tokens -> 2 pages,
        # against a 5-page lifetime
        assert len(req.pages) == 2
        assert sched.rm.lifetime_pages(req) == 5

    def test_packs_more_concurrent_requests_than_lifetime_reservation(self):
        """5 requests x 5 lifetime pages = 25 > the 11-page pool, but
        admission costs only 2 pages each — all five co-reside where
        whole-lifetime reservation could admit at most two."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=12, max_slots=5,
                                max_blocks=5, segment_len=4)
        sched = ContinuousBatchingScheduler(pcfg)
        for i in range(5):
            sched.submit(Request(rid=i, prompt=np.zeros(8, np.int32),
                                 max_new_tokens=24))
        assert len(sched.try_admit()) == 5

    def test_growth_happens_and_tokens_match_contiguous(self):
        """max_new far beyond one segment: pages arrive across several
        boundaries (pages_grown > 0) and tokens still equal the
        contiguous reference."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 12, 2
        prompts = _prompts(cfg, n, prompt_len, seed=17)
        base = _contiguous_tokens(model, params, prompts, gen)
        blocks = -(-(prompt_len + gen + 1) // 8)
        pcfg = PagedCacheConfig(page_size=8, n_pages=n * blocks + 1,
                                max_slots=n, max_blocks=blocks,
                                segment_len=2)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        stats = PagedServingEngine(model, pcfg).run(reqs, params)
        assert stats["pages_grown"] > 0
        assert stats["preemptions"] == 0       # pool fits both lifetimes
        for r in reqs:
            assert r.tokens == base[r.rid]


# ------------------------------ resource manager: preemption + restore
class TestPreemptionRestore:
    def test_oversubscribed_bit_identical_to_unconstrained(self):
        """Acceptance: total lifetime demand exceeds the pool, at least
        one preempt/restore cycle runs, every request completes, and
        per-request tokens are bit-identical to an unconstrained run."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 12, 4
        prompts = _prompts(cfg, n, prompt_len, seed=23)
        blocks = -(-(prompt_len + gen + 1) // 8)       # 4-page lifetime
        big = PagedCacheConfig(page_size=8, n_pages=n * blocks + 1,
                               max_slots=n, max_blocks=blocks,
                               segment_len=4)
        mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                              max_new_tokens=gen) for i in range(n)]
        reqs_u = mk()
        stats_u = PagedServingEngine(model, big).run(reqs_u, params)
        assert stats_u["preemptions"] == 0
        base = {r.rid: list(r.tokens) for r in reqs_u}
        # pool covers every admission (3 pages each) but not the
        # lifetimes (4 each): growth must preempt
        small = PagedCacheConfig(page_size=8, n_pages=n * 3 + 1,
                                 max_slots=n, max_blocks=blocks,
                                 segment_len=4)
        reqs = mk()
        stats = PagedServingEngine(model, small).run(reqs, params)
        assert stats["n_finished"] == n
        assert stats["preemptions"] >= 1
        assert stats["restores"] == stats["preemptions"]
        assert stats["pages_swapped_out"] > 0
        assert {r.rid: list(r.tokens) for r in reqs} == base

    def test_same_boundary_restore_and_fresh_sharer(self):
        """Regression: a fresh admission that prefix-shares a restore's
        pages at the SAME boundary must not prefill before the restore's
        host-image scatter has dispatched — full-chunk trie entries are
        matchable pre-ready by design, so the engine orders restores
        first.  Geometry: w1/w2 fill the 3 slots with r; their growth
        preempts r; both retire at one boundary, freeing slots+pages so
        r's restore and f's admission (same prompt as r) land together,
        with f sharing r's freshly re-allocated (scatter-pending) page."""
        cfg, model, params = _smoke_setup()
        P = np.asarray(lm_tokens(16, cfg.vocab_size,
                                 seed=77)).astype(np.int32)
        fillers = [np.asarray(lm_tokens(16, cfg.vocab_size,
                                        seed=78 + i)).astype(np.int32)
                   for i in range(2)]
        mk = lambda: [  # noqa: E731
            Request(rid="w1", prompt=fillers[0].copy(), max_new_tokens=8),
            Request(rid="w2", prompt=fillers[1].copy(), max_new_tokens=8),
            Request(rid="r", prompt=P.copy(), max_new_tokens=12),
            Request(rid="f", prompt=P.copy(), max_new_tokens=6)]
        big = PagedCacheConfig(page_size=8, n_pages=4 * 4 + 1,
                               max_slots=3, max_blocks=4, segment_len=4)
        ru = mk()
        PagedServingEngine(model, big).run(ru, params)
        base = {r.rid: list(r.tokens) for r in ru}
        small = PagedCacheConfig(page_size=8, n_pages=10, max_slots=3,
                                 max_blocks=4, segment_len=4)
        rs = mk()
        stats = PagedServingEngine(model, small).run(rs, params)
        assert stats["preemptions"] >= 1 and stats["restores"] >= 1
        assert stats["prefix_hits"] >= 1          # f did share r's pages
        assert {r.rid: list(r.tokens) for r in rs} == base

    def test_victim_policy_skips_protected_and_prefers_newest(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=4,
                                max_blocks=4, segment_len=4)
        sched = ContinuousBatchingScheduler(pcfg)
        for i in range(3):
            sched.submit(Request(rid=i, prompt=np.zeros(8, np.int32),
                                 max_new_tokens=4))
        admitted = sched.try_admit()
        assert len(admitted) == 3
        rm = sched.rm
        # all fresh admissions carry one segment of protection
        assert rm.pick_victim(sched.running.values(),
                              exclude=admitted[0]) is None
        sched.end_segment(r.slot for r in admitted)    # all generated
        victim = rm.pick_victim(sched.running.values(),
                                exclude=admitted[0])
        assert victim is admitted[2]                   # newest first
        admitted[2].protected = True                   # restored-like
        victim = rm.pick_victim(sched.running.values(),
                                exclude=admitted[0])
        assert victim is admitted[1]

    def test_restore_rematches_resident_prefix_pages(self):
        """A preempted request whose prompt prefix is still resident
        (its prefix owner kept running) restores by block-table aliasing
        for those pages and host swap-in only for the rest."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=2,
                                max_blocks=6, segment_len=4)
        sched = ContinuousBatchingScheduler(pcfg)
        prompt = np.arange(16, dtype=np.int32)
        owner = Request(rid="o", prompt=prompt, max_new_tokens=16)
        sharer = Request(rid="s", prompt=prompt.copy(), max_new_tokens=16)
        sched.submit(owner)
        sched.submit(sharer)
        admitted = sched.try_admit()
        assert len(admitted) == 2
        sched.finish_boundary(admitted)                # trie ready
        for r, ngen in ((owner, 4), (sharer, 2)):
            r.tokens = list(range(ngen))               # fake generation
        sched.end_segment([owner.slot, sharer.slot])
        owner_page0 = owner.pages[0]
        sched._preempt(sharer)
        assert sharer.swap is not None
        assert sharer.swap.n_tokens == 16 + 2 - 1      # sl = p + n_gen - 1
        (back,) = sched.try_admit()
        assert back is sharer
        # first prompt page re-mapped from the live owner, not swapped in
        assert back.restore_blocks[0] >= 1
        assert back.pages[0] == owner_page0
        assert sched.allocator.refcount(owner_page0) >= 2
        assert sched.rm.pages_swapped_in < sched.rm.pages_swapped_out

    def test_quota_preemption_stays_inside_the_tenant(self):
        """A tenant at its budget evicts its own newest request; other
        tenants' requests are never quota victims."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=6, segment_len=4)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", page_budget=5),
                           TenantConfig("b", page_budget=48)])
        reqs = [Request(rid=f"a{i}", prompt=np.zeros(8, np.int32),
                        max_new_tokens=24, tenant="a") for i in range(2)]
        other = Request(rid="b0", prompt=np.zeros(8, np.int32),
                        max_new_tokens=24, tenant="b")
        for r in [*reqs, other]:
            sched.submit(r)
        admitted = sched.try_admit()
        assert len(admitted) == 3
        for r in admitted:
            r.tokens = list(range(6))       # deep enough to need growth
        sched.end_segment(r.slot for r in admitted)
        preempted = sched.plan_growth()
        # tenant a is over budget for its growth: its newest request is
        # swapped; tenant b grows freely and is never touched
        assert preempted and all(r.tenant == "a" for r in preempted)
        assert other in sched.running.values()


# --------------------------- resource manager: tenants, DRR, retention
class TestTenantScheduling:
    def _mk(self, rid, tenant, max_new=4):
        return Request(rid=rid, prompt=np.zeros(8, np.int32),
                       max_new_tokens=max_new, tenant=tenant)

    def test_weighted_drr_admission_split(self):
        """Three slots, two tenants at weight 2:1 with equal-cost
        queues: the weight-2 tenant lands two of the three slots."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=3,
                                max_blocks=4, segment_len=4)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", weight=2.0),
                           TenantConfig("b", weight=1.0)])
        for i in range(4):
            sched.submit(self._mk(f"a{i}", "a"))
            sched.submit(self._mk(f"b{i}", "b"))
        admitted = sched.try_admit()
        assert len(admitted) == 3
        by_tenant = {t: sum(r.tenant == t for r in admitted)
                     for t in ("a", "b")}
        assert by_tenant == {"a": 2, "b": 1}

    def test_budget_blocks_admission_until_pages_refund(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=4, segment_len=8)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", page_budget=3)])
        r1, r2 = self._mk("a0", "a", 8), self._mk("a1", "a", 8)
        sched.submit(r1)
        sched.submit(r2)
        admitted = sched.try_admit()   # 3 pages each: budget fits one
        assert [r.rid for r in admitted] == ["a0"]
        assert sched.rm.headroom("a") == 0
        sched.complete(r1.slot)        # refund through release_request
        assert sched.rm.headroom("a") == 3
        assert [r.rid for r in sched.try_admit()] == ["a1"]

    def test_lifetime_beyond_budget_rejected_at_submit(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=6)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", page_budget=2)])
        with pytest.raises(ValueError):
            sched.submit(self._mk("a0", "a", max_new=24))  # 5 pages

    def test_unknown_tenant_rejected_when_roster_is_explicit(self):
        """A typo'd tenant must not auto-register with a whole-pool
        budget and route around the configured quotas."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=4)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", page_budget=4)])
        with pytest.raises(ValueError, match="unknown tenant"):
            sched.submit(self._mk("x0", "a-typo"))
        # without a roster, any tenant name auto-registers (single-tenant
        # callers never mention tenants at all)
        open_sched = ContinuousBatchingScheduler(pcfg)
        open_sched.submit(self._mk("x0", "whatever"))
        assert len(open_sched.try_admit()) == 1

    def test_shared_prefix_pages_charge_only_marginal_cost(self):
        """A sharer whose prompt prefix is resident pays only for its
        CoW fork + suffix/decode pages — the shared pages never count
        against its tenant's budget."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=6, segment_len=4)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a", page_budget=32),
                           TenantConfig("b", page_budget=5)])
        prompt = np.arange(24, dtype=np.int32)
        owner = Request(rid="o", prompt=prompt, max_new_tokens=8,
                        tenant="a")
        sched.submit(owner)
        sched.finish_boundary(sched.try_admit())
        owner_charged = owner.charged
        # sharer: 2 full prompt pages map free of charge — only the CoW
        # fork page and the fresh suffix/decode page are billed
        sharer = Request(rid="s", prompt=prompt.copy(), max_new_tokens=8,
                         tenant="b")
        sched.submit(sharer)
        (adm,) = sched.try_admit()
        assert adm is sharer
        assert sharer.shared_pages == 2
        assert sharer.charged == len(sharer.pages) - 2 == 2
        assert owner.charged == owner_charged  # owner pays for its own

    def test_tenant_stats_schema(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=2,
                                max_blocks=4)
        sched = ContinuousBatchingScheduler(
            pcfg, tenants=[TenantConfig("a")])
        sched.submit(self._mk("a0", "a"))
        sched.try_admit()
        stats = sched.stats()
        ta = stats["tenants"]["a"]
        for key in ("admitted", "preempted", "restored", "pages_swapped",
                    "pages_charged", "page_budget", "queued"):
            assert key in ta
        assert ta["admitted"] == 1 and ta["preempted"] == 0


class TestPrefixRetention:
    def test_pins_keep_prefix_alive_past_owner_completion(self):
        """With retain_pages set, completing the last request holding a
        prefix does NOT free its full-chunk pages — a later identical
        prompt still hits the trie."""
        pcfg = PagedCacheConfig(page_size=8, n_pages=32, max_slots=2,
                                max_blocks=6, segment_len=4,
                                retain_pages=2)
        sched = ContinuousBatchingScheduler(pcfg)
        prompt = np.arange(24, dtype=np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        sched.submit(req)
        sched.finish_boundary(sched.try_admit())   # pins the 2 full pages
        assert sched.prefix_cache.pinned_pages == 2
        pinned = req.pages[:2]
        sched.complete(req.slot)
        # pinned pages survive the owner's completion at refcount 1
        assert all(sched.allocator.refcount(p) == 1 for p in pinned)
        late = Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)
        sched.submit(late)
        (adm,) = sched.try_admit()
        assert adm.shared_pages == 2
        assert adm.pages[:2] == pinned

    def test_pins_evict_under_allocator_pressure(self):
        """Retention never wins against a request's demand: an admission
        that needs the pinned pages gets them."""
        # 5 allocatable pages, 2 of them pinned after the owner leaves
        pcfg = PagedCacheConfig(page_size=8, n_pages=6, max_slots=2,
                                max_blocks=5, segment_len=8,
                                retain_pages=2)
        sched = ContinuousBatchingScheduler(pcfg)
        req = Request(rid=0, prompt=np.arange(24, dtype=np.int32),
                      max_new_tokens=8)
        sched.submit(req)
        sched.finish_boundary(sched.try_admit())
        sched.complete(req.slot)
        assert sched.prefix_cache.pinned_pages == 2
        assert sched.allocator.n_free == 3
        # unrelated request needing 5 pages: pins must yield
        big = Request(rid=1, prompt=100 + np.arange(24, dtype=np.int32),
                      max_new_tokens=8)
        sched.submit(big)
        (adm,) = sched.try_admit()
        assert adm is big and len(adm.pages) == 5
        assert sched.prefix_cache.pin_evictions >= 2
        assert sched.stats()["pin_evictions"] >= 2

    def test_pin_budget_is_lru_capped(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=64, max_slots=4,
                                max_blocks=6, segment_len=4,
                                retain_pages=3)
        sched = ContinuousBatchingScheduler(pcfg)
        for i in range(3):
            prompt = (100 * i + np.arange(24)).astype(np.int32)
            r = Request(rid=i, prompt=prompt, max_new_tokens=4)
            sched.submit(r)
            sched.finish_boundary(sched.try_admit())
            sched.complete(r.slot)
        pc = sched.prefix_cache
        assert pc.pinned_pages == 3          # capped, LRU evicted
        assert pc.pin_evictions == 6         # 9 candidate pins, 3 kept


# -------------------------------------------- segment-length autotuning
class TestSegmentAutotune:
    def test_registered_and_tunable(self, tmp_path):
        from repro.kernels import autotune
        prob = autotune.paged_segment_problem(2, 4, 2, 8, 24, 8,
                                              "float32")
        cands = autotune.enumerate_candidates("paged_segment", prob)
        assert {"segment_len": 8} in [c for c, _ in cands]   # default
        res = autotune.tune("paged_segment", prob,
                            cache_path=str(tmp_path / "c.json"), iters=1)
        assert res.config["segment_len"] >= 1
        again = autotune.tune("paged_segment", prob,
                              cache_path=str(tmp_path / "c.json"),
                              iters=1)
        assert again.cached and again.config == res.config

    def test_tune_task_derives_segment_problem(self):
        from repro.tasks.tune import derive_problems
        from repro.tasks.handle import DNNHandle
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        handle = DNNHandle(kind="lm", name="m",
                           params=model.init(KEY), model=model)
        kernels = [p["kernel"]
                   for p in derive_problems(handle, max_problems=16)]
        assert "paged_segment" in kernels
        wcfg = get_config("h2o_danube_3_4b", smoke=True)   # windowed
        wmodel = build_model(wcfg)
        whandle = DNNHandle(kind="lm", name="w",
                            params=wmodel.init(KEY), model=wmodel)
        wkernels = [p["kernel"]
                    for p in derive_problems(whandle, max_problems=16)]
        assert "paged_segment" not in wkernels

    def test_preferred_segment_len_readback(self, tmp_path, monkeypatch):
        from repro.kernels import autotune
        from repro.serving.paged_cache import preferred_segment_len
        cache = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", cache)
        autotune.clear_memory_cache()
        cfg = get_config("qwen2_7b", smoke=True)
        # cold cache: the kernel default stands in
        assert preferred_segment_len(cfg, 4, 48) == 8
        # a persisted winner (keyed on the tuned page size) is read back
        prob = autotune.paged_segment_problem(
            4, cfg.n_heads, cfg.n_kv_heads, cfg.hd, 48, 16,
            str(cfg.adt))
        autotune._store(cache, autotune.cache_key("paged_segment", prob),
                        {"config": {"segment_len": 16}, "us": 1.0,
                         "n_trials": 5, "iters": 3,
                         "backend": jax.default_backend(), "t": 0.0})
        autotune.clear_memory_cache()
        assert preferred_segment_len(cfg, 4, 48) == 16
        autotune.clear_memory_cache()

    def test_growth_granule_follows_segment_len(self):
        pcfg = PagedCacheConfig(page_size=8, segment_len=12)
        assert pcfg.growth_granule == 2      # pages_for(12)
        assert PagedCacheConfig(page_size=8, segment_len=12,
                                growth_pages=3).growth_granule == 3
