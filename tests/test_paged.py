"""Paged KV-cache + continuous batching: allocator, kernel-vs-oracle,
paged-vs-contiguous token equality, page reuse, scheduler admit/evict."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.data.synthetic import lm_tokens
from repro.kernels.flash_decode_paged import flash_decode_paged
from repro.launch.serve import generate, make_serve_fns
from repro.models import layers as L
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingScheduler, PageAllocator,
                           PagedCacheConfig, PagedServingEngine, Request,
                           TRASH_PAGE, init_paged_cache)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- allocator
class TestPageAllocator:
    def test_alloc_free_reuse(self):
        a = PageAllocator(8)                       # pages 1..7 allocatable
        p1 = a.alloc(3)
        assert p1 == [1, 2, 3]
        assert a.n_free == 4
        a.release(p1)
        assert a.n_free == 7
        # freed pages are reused first, lowest-first
        assert a.alloc(2) == [1, 2]

    def test_never_hands_out_trash_page(self):
        a = PageAllocator(4)
        assert TRASH_PAGE not in a.alloc(3)

    def test_all_or_nothing(self):
        a = PageAllocator(4)
        assert a.alloc(3) is not None
        assert a.alloc(1) is None                  # exhausted
        assert a.n_free == 0

    def test_double_free_rejected(self):
        a = PageAllocator(4)
        p = a.alloc(2)
        a.release(p)
        with pytest.raises(ValueError):
            a.release(p)

    def test_capacity_validation(self):
        pcfg = PagedCacheConfig(page_size=4, n_pages=8, max_slots=2,
                                max_blocks=2)
        with pytest.raises(ValueError):
            pcfg.validate_request(prompt_len=8, max_new_tokens=4)
        assert pcfg.validate_request(prompt_len=4, max_new_tokens=3) == 2


# ------------------------------------------------- position/mask helpers
class TestPagedMaskHelpers:
    def test_matches_contiguous_helpers(self):
        """Per-request paged positions/mask rows must equal the linear
        contiguous-cache helpers at the same position."""
        n_slots = 24
        seq_lens = jnp.asarray([0, 5, 23], jnp.int32)
        kv_pos = L.paged_kv_positions(seq_lens, n_slots)
        mask = L.paged_decode_attention_mask(kv_pos, seq_lens)
        for i, pos in enumerate([0, 5, 23]):
            ref_pos = L.kv_positions_for_cache(jnp.asarray(pos), n_slots, 0)
            ref_mask = L.decode_attention_mask(ref_pos, pos, 0)
            assert bool(jnp.all(kv_pos[i] == ref_pos))
            assert bool(jnp.all(mask[i] == ref_mask))

    def test_ragged_rows(self):
        seq_lens = jnp.asarray([2, 7], jnp.int32)
        mask = L.paged_decode_attention_mask(
            L.paged_kv_positions(seq_lens, 8), seq_lens)
        assert mask.astype(int).sum(axis=1).tolist() == [3, 8]


# ------------------------------------------------------ kernel vs oracle
def _paged_problem(key, slots, h, kvh, d, page_size, blocks, seq_lens):
    """Random pages + a scrambled block table + the shared mask."""
    ks = jax.random.split(key, 4)
    n_pages = slots * blocks + 1
    q = jax.random.normal(ks[0], (slots, 1, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, page_size, kvh, d),
                           jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, page_size, kvh, d),
                           jnp.float32)
    perm = jax.random.permutation(ks[3], n_pages - 1) + 1
    bt = perm[:slots * blocks].reshape(slots, blocks).astype(jnp.int32)
    sl = jnp.asarray(seq_lens, jnp.int32)
    mask = L.paged_decode_attention_mask(
        L.paged_kv_positions(sl, blocks * page_size), sl)
    return q, kp, vp, bt, mask


def _oracle(q, kp, vp, bt, mask):
    slots, _, h, d = q.shape
    _, ps, kvh, _ = kp.shape
    blocks = bt.shape[1]
    kf = kp[bt].reshape(slots, blocks * ps, kvh, d)
    vf = vp[bt].reshape(slots, blocks * ps, kvh, d)
    k_exp = L._expand_kv(kf, h)
    v_exp = L._expand_kv(vf, h)
    s = jnp.einsum("bqhd,bkhd->bhqk",
                   q.astype(jnp.float32) / math.sqrt(d),
                   k_exp.astype(jnp.float32))
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v_exp.astype(jnp.float32))


class TestPagedKernelVsOracle:
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (7, 1)])
    @pytest.mark.parametrize("page_size", [4, 8, 16])
    def test_gqa_and_page_size_grid(self, h, kvh, page_size):
        slots, blocks, d = 3, 3, 8
        cap = blocks * page_size
        seq_lens = [0, cap // 2, cap - 1]          # empty-ish / mid / full
        q, kp, vp, bt, mask = _paged_problem(
            KEY, slots, h, kvh, d, page_size, blocks, seq_lens)
        out = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        ref = _oracle(q, kp, vp, bt, mask)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    @pytest.mark.parametrize("seq_lens", [[0, 1, 2, 3], [5, 17, 9, 30]])
    def test_ragged_lengths(self, seq_lens):
        slots, blocks, ps, h, kvh, d = 4, 4, 8, 4, 2, 8
        q, kp, vp, bt, mask = _paged_problem(
            jax.random.PRNGKey(7), slots, h, kvh, d, ps, blocks, seq_lens)
        out = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        ref = _oracle(q, kp, vp, bt, mask)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5

    def test_scrambled_vs_identity_block_table(self):
        """Physical page placement must be invisible: the same logical
        K/V through a scrambled table equals an identity layout."""
        slots, blocks, ps, h, kvh, d = 2, 3, 4, 4, 2, 8
        seq_lens = [7, 11]
        q, kp, vp, bt, mask = _paged_problem(
            jax.random.PRNGKey(3), slots, h, kvh, d, ps, blocks, seq_lens)
        ident_bt = 1 + jnp.arange(slots * blocks,
                                  dtype=jnp.int32).reshape(slots, blocks)
        kp_i = kp.at[ident_bt.reshape(-1)].set(kp[bt.reshape(-1)])
        vp_i = vp.at[ident_bt.reshape(-1)].set(vp[bt.reshape(-1)])
        out_s = flash_decode_paged(q, kp, vp, bt, mask, interpret=True)
        out_i = flash_decode_paged(q, kp_i, vp_i, ident_bt, mask,
                                   interpret=True)
        assert float(jnp.max(jnp.abs(out_s - out_i))) < 1e-6


# ------------------------------------- engine: paged vs contiguous tokens
def _smoke_setup():
    cfg = get_config("qwen2_7b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _prompts(cfg, n, prompt_len, seed=1):
    return np.asarray(lm_tokens(n * prompt_len, cfg.vocab_size, seed=seed)
                      ).reshape(n, prompt_len).astype(np.int32)


def _contiguous_tokens(model, params, prompts, gen):
    fns = make_serve_fns(model)
    out = {}
    for i in range(prompts.shape[0]):
        toks = generate(model, params, jnp.asarray(prompts[i:i + 1]), gen,
                        prompts.shape[1] + gen + 1, scan=True, fns=fns)
        out[i] = [int(t) for t in np.asarray(toks)[0]]
    return out


class TestPagedEngineTokens:
    @pytest.mark.parametrize("page_size", [8, 16, 32])
    def test_tokens_equal_contiguous_across_page_sizes(self, page_size):
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 9, 3
        prompts = _prompts(cfg, n, prompt_len)
        base = _contiguous_tokens(model, params, prompts, gen)
        blocks = -(-(prompt_len + gen + 1) // page_size)
        pcfg = PagedCacheConfig(page_size=page_size,
                                n_pages=2 * blocks * 2 + 1,
                                max_slots=2, max_blocks=blocks,
                                segment_len=4)
        eng = PagedServingEngine(model, pcfg)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        eng.run(reqs, params)
        for r in reqs:
            assert r.tokens == base[r.rid], (page_size, r.rid)

    def test_kernel_path_tokens_equal_oracle_path(self):
        cfg, model, params = _smoke_setup()
        model_k = build_model(cfg, use_kernels=True, interpret=True)
        prompt_len, gen, n = 16, 8, 3
        prompts = _prompts(cfg, n, prompt_len, seed=5)
        pcfg = PagedCacheConfig(page_size=8, n_pages=16, max_slots=2,
                                max_blocks=4, segment_len=4)
        res = {}
        for name, mdl in (("oracle", model), ("kernel", model_k)):
            reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                    for i in range(n)]
            PagedServingEngine(mdl, pcfg).run(reqs, params)
            res[name] = {r.rid: r.tokens for r in reqs}
        assert res["oracle"] == res["kernel"]

    def test_ragged_max_new_tokens(self):
        """Requests finishing at different steps: each still matches its
        own contiguous reference."""
        cfg, model, params = _smoke_setup()
        prompt_len = 16
        gens = [3, 11, 7, 5]
        prompts = _prompts(cfg, len(gens), prompt_len, seed=9)
        fns = make_serve_fns(model)
        base = {}
        for i, g in enumerate(gens):
            toks = generate(model, params, jnp.asarray(prompts[i:i + 1]),
                            g, prompt_len + g + 1, scan=True, fns=fns)
            base[i] = [int(t) for t in np.asarray(toks)[0]]
        pcfg = PagedCacheConfig(page_size=8, n_pages=16, max_slots=3,
                                max_blocks=4, segment_len=4)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=g)
                for i, g in enumerate(gens)]
        PagedServingEngine(model, pcfg).run(reqs, params)
        for r in reqs:
            assert len(r.tokens) == gens[r.rid]
            assert r.tokens == base[r.rid]

    def test_page_reuse_after_completion(self):
        """A pool sized for ~one request at a time forces later requests
        onto recycled pages; tokens must stay correct."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 6, 4
        prompts = _prompts(cfg, n, prompt_len, seed=3)
        base = _contiguous_tokens(model, params, prompts, gen)
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=2,
                                max_blocks=3, segment_len=2)
        # pages_for(16+6+1)=3 = entire allocatable pool: strictly serial
        # admission, every admission after the first reuses freed pages
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        eng = PagedServingEngine(model, pcfg)
        eng.run(reqs, params)
        for r in reqs:
            assert r.tokens == base[r.rid]


# -------------------------------------------------------------- scheduler
class TestScheduler:
    def test_admit_evict_across_segments(self):
        """More requests than slots: admissions must be spread over the
        run (continuous batching), not all up front, and every request
        completes with freed pages accounted for."""
        cfg, model, params = _smoke_setup()
        prompt_len, gen, n = 16, 6, 5
        prompts = _prompts(cfg, n, prompt_len, seed=11)
        pcfg = PagedCacheConfig(page_size=8, n_pages=8, max_slots=2,
                                max_blocks=3, segment_len=2)
        eng = PagedServingEngine(model, pcfg)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
                for i in range(n)]
        stats = eng.run(reqs, params)
        assert stats["n_finished"] == n
        assert all(len(r.tokens) == gen for r in reqs)
        # 5 requests through 2 slots cannot be co-resident: admissions
        # must span multiple scheduler syncs
        admit_times = sorted(r.t_admitted for r in reqs)
        done_times = sorted(r.t_done for r in reqs)
        assert admit_times[-1] > done_times[0]

    def test_admission_blocks_on_pages_not_just_slots(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=4,
                                max_blocks=3)
        sched = ContinuousBatchingScheduler(pcfg)
        for i in range(3):
            sched.submit(Request(rid=i, prompt=np.zeros(8, np.int32),
                                 max_new_tokens=8))
        admitted = sched.try_admit()
        # each request needs pages_for(8+8+1)=3 pages; pool has 3 free
        assert len(admitted) == 1
        assert sched.pending and sched.free_slots
        sched.complete(admitted[0].slot)
        assert len(sched.try_admit()) == 1

    def test_fifo_no_overtaking(self):
        pcfg = PagedCacheConfig(page_size=8, n_pages=4, max_slots=4,
                                max_blocks=3)
        sched = ContinuousBatchingScheduler(pcfg)
        big = Request(rid="big", prompt=np.zeros(16, np.int32),
                      max_new_tokens=7)
        small = Request(rid="small", prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)
        filler = Request(rid="filler", prompt=np.zeros(8, np.int32),
                         max_new_tokens=6)
        sched.submit(filler)
        assert [r.rid for r in sched.try_admit()] == ["filler"]  # 2 pages
        sched.submit(big)      # needs 3 pages, only 1 free
        sched.submit(small)    # would fit, but must not overtake big
        assert sched.try_admit() == []

    def test_trash_page_never_allocated(self):
        cfg, _, _ = _smoke_setup()
        pcfg = PagedCacheConfig(page_size=8, n_pages=6, max_slots=2,
                                max_blocks=3)
        cache, _ = init_paged_cache(cfg, pcfg)
        assert bool(jnp.all(cache["block_tables"] == TRASH_PAGE))
        sched = ContinuousBatchingScheduler(pcfg)
        sched.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                             max_new_tokens=8))
        (req,) = sched.try_admit()
        assert TRASH_PAGE not in req.pages

    def test_paging_gated_families(self):
        from repro.serving.paged_cache import supports_paging
        assert supports_paging(get_config("qwen2_7b", smoke=True))
        assert not supports_paging(
            get_config("h2o_danube_3_4b", smoke=True))   # sliding window
        assert not supports_paging(
            get_config("zamba2_2p7b", smoke=True))       # hybrid SSM
        with pytest.raises(ValueError):
            PagedServingEngine(
                build_model(get_config("h2o_danube_3_4b", smoke=True)),
                PagedCacheConfig())


# ------------------------------------------------------ autotune problem
class TestPagedAutotune:
    def test_registered_and_tunable(self, tmp_path):
        from repro.kernels import autotune
        prob = autotune.flash_decode_paged_problem(2, 4, 2, 8, 16,
                                                   "float32")
        cands = autotune.enumerate_candidates("flash_decode_paged", prob)
        assert {"page_size": 16} in [c for c, _ in cands]  # default
        res = autotune.tune("flash_decode_paged", prob,
                            cache_path=str(tmp_path / "c.json"), iters=1)
        assert res.config["page_size"] >= 1
        again = autotune.tune("flash_decode_paged", prob,
                              cache_path=str(tmp_path / "c.json"),
                              iters=1)
        assert again.cached and again.config == res.config

    def test_tune_task_derives_paged_problem(self):
        from repro.tasks.tune import derive_problems
        from repro.tasks.handle import DNNHandle
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        params = model.init(KEY)
        handle = DNNHandle(kind="lm", name="m", params=params,
                           model=model)
        probs = derive_problems(handle, max_problems=16)
        kernels = [p["kernel"] for p in probs]
        assert "flash_decode_paged" in kernels
        # windowed arch: ring-buffer cache is not paged -> no paged problem
        wcfg = get_config("h2o_danube_3_4b", smoke=True)
        wmodel = build_model(wcfg)
        whandle = DNNHandle(kind="lm", name="w", params=wmodel.init(KEY),
                            model=wmodel)
        wkernels = [p["kernel"]
                    for p in derive_problems(whandle, max_problems=16)]
        assert "flash_decode_paged" not in wkernels
