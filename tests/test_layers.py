"""Attention-layer numerics: MEA vs naive softmax, MLA cache equivalence,
MoE routing invariants (single-device paths)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.ref import flash_attention_ref
from repro.models import layers as L
from repro.models.common import Ctx

KEY = jax.random.PRNGKey(0)


class TestMEA:
    @pytest.mark.parametrize("sq,chunk", [(64, 16), (64, 64), (50, 16)])
    def test_matches_naive(self, sq, chunk):
        b, h, d = 2, 3, 16
        q = jax.random.normal(KEY, (b, sq, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, sq, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, sq, h, d))
        pos = jnp.arange(sq)
        y = L.mea_attention(q, k, v, pos, pos, causal=True, chunk=chunk)
        r = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16_operand_mode_close(self):
        b, s, h, d = 1, 64, 2, 32
        q = jax.random.normal(KEY, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        pos = jnp.arange(s)
        y32 = L.mea_attention(q, k, v, pos, pos, causal=True, chunk=16)
        y16 = L.mea_attention(q, k, v, pos, pos, causal=True, chunk=16,
                              bf16_operands=True)
        assert float(jnp.max(jnp.abs(y32 - y16))) < 0.03

    def test_window_masks_old_tokens(self):
        """With window=W, positions older than W contribute nothing."""
        b, s, h, d = 1, 32, 1, 8
        q = jax.random.normal(KEY, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        pos = jnp.arange(s)
        y = L.mea_attention(q, k, v, pos, pos, causal=True, window=4,
                            chunk=8)
        # perturb kv outside every query's window: outputs identical
        k2 = k.at[:, :s - 8].set(jax.random.normal(
            jax.random.PRNGKey(3), (b, s - 8, h, d)))
        v2 = v.at[:, :s - 8].set(0.0)
        y2 = L.mea_attention(q, k2, v2, pos, pos, causal=True, window=4,
                             chunk=8)
        np.testing.assert_allclose(np.asarray(y[:, -3:]),
                                   np.asarray(y2[:, -3:]), atol=1e-5)


class TestMLA:
    def test_cache_decode_matches_full(self):
        cfg = get_config("deepseek_v2_236b", smoke=True).replace(
            act_dtype="float32")
        p, _ = L.init_mla(KEY, cfg)
        b, s = 2, 12
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (b, s, cfg.d_model)) * 0.3
        pos = jnp.arange(s)
        ctx = Ctx()
        y_full, _ = L.mla_attention(ctx, cfg, p, x, pos)
        cache, _ = L.init_mla_cache(cfg, b, 16, dtype=jnp.float32)
        _, cache = L.mla_attention(ctx, cfg, p, x[:, :s - 1],
                                   jnp.arange(s - 1), cache)
        y_dec, _ = L.mla_attention(Ctx(decode=True), cfg, p,
                                   x[:, s - 1:], jnp.asarray([s - 1]),
                                   cache)
        err = float(jnp.max(jnp.abs(y_dec - y_full[:, -1:])))
        assert err < 1e-4, err

    def test_cache_is_compressed(self):
        """MLA cache stores kv_lora + rope dims, not full K/V — the point
        of MLA (paper config kv_lora=512 vs 128 heads x 192)."""
        cfg = get_config("deepseek_v2_236b", smoke=True)
        cache, _ = L.init_mla_cache(cfg, 2, 16)
        full_kv = 2 * cfg.n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
        stored = cache["ckv"].shape[-1] + cache["krope"].shape[-1]
        assert stored < full_kv / 4


class TestMoE:
    def test_router_topk_gates_normalized(self):
        cfg = get_config("granite_moe_1b_a400m", smoke=True)
        x2 = jax.random.normal(KEY, (10, cfg.d_model))
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.d_model, cfg.n_experts))
        gates, eidx = L._router(cfg, w, x2)
        assert gates.shape == (10, cfg.top_k)
        np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)),
                                   np.ones(10), rtol=1e-5)
        assert int(jnp.max(eidx)) < cfg.n_experts

    def test_rank_in_expert(self):
        ids = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
        pos = L._rank_in_expert(ids, 3)
        # expert 2 receives tokens at flat idx 0,2,4 -> ranks 0,1,2
        assert pos.tolist() == [0, 0, 1, 0, 2, 1]

    def test_aux_loss_balanced_vs_skewed(self):
        cfg = get_config("granite_moe_1b_a400m", smoke=True)
        d, e = cfg.d_model, cfg.n_experts
        x = jax.random.normal(KEY, (1, 64, d))
        w_uniform = jnp.zeros((d, e))
        aux_u = L.moe_aux_loss(cfg, w_uniform, x)
        # skew router towards expert 0
        w_skew = jnp.zeros((d, e)).at[:, 0].set(5.0)
        aux_s = L.moe_aux_loss(cfg, w_skew, x)
        assert float(aux_s) > float(aux_u)


class TestElasticRestore:
    def test_checkpoint_restores_onto_new_sharding(self, tmp_path):
        """Save unsharded, restore with explicit shardings (the elastic
        path used when the mesh shape changes between runs)."""
        from jax.sharding import (Mesh, NamedSharding,
                                  PartitionSpec as P)
        from repro.checkpoint.manager import CheckpointManager
        state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        ckpt.save(0, state)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        shardings = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = ckpt.restore(shardings=shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["w"].sharding == shardings["w"]
