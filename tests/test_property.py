"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.search import binary_search_max
from repro.models.common import apply_rope
from repro.optim.compression import compress_with_feedback
from repro.quant.policy import (INT8, LEVELS, PrecisionPolicy, cast_level,
                                quantize_int8)
from repro.serving import (ContinuousBatchingScheduler, PagedCacheConfig,
                           Request, TenantConfig)
from repro.serving.paged_cache import PageAllocator
from repro.sparsity.masks import (apply_masks, block_mask, magnitude_mask,
                                  sparsity_report)

SETTINGS = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------- quantization
@SETTINGS
@given(st.integers(2, 64), st.integers(2, 64),
       st.floats(0.1, 100.0))
def test_int8_quant_error_bounded(rows, cols, scale_mag):
    """|dequant - w| <= absmax/127 * 0.5 per output channel (+eps)."""
    w = np.random.default_rng(rows * cols).normal(
        0, scale_mag, (rows, cols)).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(w), axis=0)
    deq = np.asarray(q, np.float32) * np.asarray(scale)
    bound = np.asarray(scale)[0] * 0.5 + 1e-6
    assert np.all(np.abs(deq - w) <= bound + 1e-4 * scale_mag)


@SETTINGS
@given(st.sampled_from(LEVELS))
def test_cast_level_idempotent(level):
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (16, 16)),
                    jnp.float32)
    once = cast_level(w, level)
    twice = cast_level(once, level)
    if level == INT8:
        # int8 re-quantization of an already-quantized tensor may shift by
        # one LSB of the (rescaled) grid; bound it instead of exact match
        _, scale = quantize_int8(once, axis=0)
        assert float(jnp.max(jnp.abs(twice - once))) <= \
            float(jnp.max(scale)) + 1e-6
    else:
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_policy_first_match_wins_and_exempt():
    p = PrecisionPolicy(default="bf16", exempt=["*router*"])
    p = p.with_rule("*mlp*", "int8")
    p = p.with_rule("*mlp/w_up*", "fp8")   # newer rule wins
    assert p.level_for("layers/mlp/w_up") == "fp8"
    assert p.level_for("layers/mlp/w_down") == "int8"
    assert p.level_for("layers/moe/router") == "bf16"
    assert p.level_for("unmatched") == "bf16"


# --------------------------------------------------------------- pruning
@SETTINGS
@given(st.integers(8, 128), st.integers(8, 128),
       st.floats(0.0, 1.0))
def test_magnitude_mask_rate(rows, cols, rate):
    w = jnp.asarray(np.random.default_rng(rows + cols).normal(
        0, 1, (rows, cols)), jnp.float32)
    m = magnitude_mask(w, rate)
    got = 1.0 - float(jnp.mean(m))
    assert abs(got - rate) <= 1.5 / (rows * cols) + 0.02


@SETTINGS
@given(st.integers(1, 4), st.integers(1, 4), st.floats(0.0, 1.0))
def test_block_mask_rate_block_resolution(bm, bn, rate):
    w = jnp.asarray(np.random.default_rng(bm * 7 + bn).normal(
        0, 1, (bm * 32, bn * 32)), jnp.float32)
    m = block_mask(w, rate, block=32)
    n_blocks = bm * bn
    zeros = n_blocks - int(jnp.sum(m) // (32 * 32))
    assert abs(zeros - round(rate * n_blocks)) <= 1


def test_apply_masks_idempotent():
    params = {"a": {"w": jnp.ones((8, 8))}}
    masks = {"a/w": jnp.asarray(np.random.default_rng(0).integers(
        0, 2, (8, 8)), jnp.float32)}
    once = apply_masks(params, masks)
    twice = apply_masks(once, masks)
    np.testing.assert_array_equal(np.asarray(once["a"]["w"]),
                                  np.asarray(twice["a"]["w"]))
    rep = sparsity_report(masks)
    assert rep["zeros"] == 64 - int(masks["a/w"].sum())


# -------------------------------------- refcounted page allocator (serving)
@settings(max_examples=60, deadline=None)
@given(st.integers(4, 24), st.data())
def test_page_allocator_interleavings_never_leak(n_pages, data):
    """Random admit / share-prefix-admit / CoW / complete interleavings:
    pages are never leaked or double-counted, refcounts always equal the
    number of live mappings, and every page whose refcount hits 0 is
    immediately reusable (returns to the free list)."""
    alloc = PageAllocator(n_pages)
    total = n_pages - 1              # page 0 is the reserved scratch page
    live: list[list[int]] = []       # block-table page lists of live reqs

    def check_invariants():
        held = [p for req in live for p in req]
        # free + distinct held partitions the allocatable pool: no leak,
        # no double-count
        assert alloc.n_free + len(set(held)) == total
        assert alloc.n_held == len(set(held))
        # refcount == number of live mappings, and refcount-0 pages are
        # exactly the free ones
        from collections import Counter
        counts = Counter(held)
        for p in range(1, n_pages):
            assert alloc.refcount(p) == counts.get(p, 0)

    for _ in range(data.draw(st.integers(1, 30), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["admit", "admit_shared", "cow", "complete"]), label="op")
        if op == "admit":
            k = data.draw(st.integers(0, total), label="n_fresh")
            pages = alloc.alloc(k)
            if pages is not None:
                assert len(set(pages)) == k and 0 not in pages
                live.append(pages)
        elif op == "admit_shared" and live:
            src = data.draw(st.sampled_from(live), label="src_req")
            if src:
                take = data.draw(st.integers(1, len(src)), label="take")
                shared = src[:take]
                alloc.share(shared)
                fresh = alloc.alloc(
                    data.draw(st.integers(0, 2), label="n_extra"))
                if fresh is None:     # all-or-nothing admission: roll back
                    alloc.release(shared)
                else:
                    live.append(shared + fresh)
        elif op == "cow" and live:
            req = data.draw(st.sampled_from(live), label="cow_req")
            if req:
                i = data.draw(st.integers(0, len(req) - 1), label="blk")
                fresh = alloc.alloc(1)
                if fresh is not None:  # fork: new page in, old ref out
                    alloc.release([req[i]])
                    req[i] = fresh[0]
        elif op == "complete" and live:
            idx = data.draw(st.integers(0, len(live) - 1), label="victim")
            alloc.release(live.pop(idx))
        check_invariants()

    for req in live:
        alloc.release(req)
    assert alloc.n_free == total     # full drain: every page came back
    with pytest.raises(ValueError):  # and nothing double-frees
        alloc.release([1])


# ----------------------- resource manager: multi-tenant state machine
@settings(max_examples=40, deadline=None)
@given(st.data())
def test_resource_manager_interleavings(data):
    """Random submit / grow / preempt / restore / complete interleavings
    across 2-3 tenants, driven through the scheduler's real boundary
    protocol with simulated generation (no model): pages never leak,
    tenant charges never exceed budgets, running coverage always backs
    the resident tokens, and every request — preempted or not — finishes.
    """
    ps = 4
    n_tenants = data.draw(st.integers(2, 3), label="n_tenants")
    tenants = [TenantConfig(f"t{i}",
                            weight=float(data.draw(
                                st.sampled_from([1, 2]), label=f"w{i}")),
                            page_budget=data.draw(
                                st.sampled_from([None, 4, 6, 8]),
                                label=f"b{i}"))
               for i in range(n_tenants)]
    pcfg = PagedCacheConfig(
        page_size=ps,
        n_pages=data.draw(st.integers(9, 25), label="n_pages"),
        max_slots=data.draw(st.integers(2, 4), label="slots"),
        max_blocks=4, segment_len=data.draw(st.integers(2, 4),
                                            label="seg"),
        retain_pages=data.draw(st.sampled_from([0, 2]), label="retain"))
    sched = ContinuousBatchingScheduler(pcfg, tenants=tenants)
    total = pcfg.allocatable_pages
    submitted: list[Request] = []
    rid = 0

    def check_invariants():
        # no page leaked or double-counted
        assert sched.allocator.n_free + sched.allocator.n_held == total
        # quota: charges within budget, and they sum consistently
        for t in tenants:
            st_ = sched.rm.state(t.name)
            assert 0 <= st_.charged <= sched.rm.budget(t.name)
        live_charge = sum(r.charged for r in sched.running.values())
        assert live_charge == sum(sched.rm.state(t.name).charged
                                  for t in tenants)
        for r in sched.running.values():
            # coverage: resident tokens always inside owned pages
            resident = r.prompt_len + max(0, len(r.tokens) - 1)
            assert len(r.pages) * ps >= resident
            assert r.swap is None

    def boundary():
        for slot, r in list(sched.running.items()):
            if len(r.tokens) >= r.max_new_tokens:
                sched.complete(slot)
        preempted = sched.plan_growth()
        for r in preempted:              # the engine would device_get here
            assert r.swap is not None and r.swap.pages
        admitted = sched.try_admit()
        for r in admitted:
            if r.swap is None and not r.tokens:
                r.tokens = [7]           # simulated prefill first token
        sched.finish_boundary(admitted)
        generated = []
        for slot, r in sched.running.items():
            if not r.stalled and len(r.tokens) < r.max_new_tokens:
                k = min(pcfg.segment_len,
                        r.max_new_tokens - len(r.tokens))
                r.tokens.extend([7] * k)
                generated.append(slot)
        sched.end_segment(generated)
        check_invariants()

    for _ in range(data.draw(st.integers(1, 25), label="n_ops")):
        op = data.draw(st.sampled_from(["submit", "boundary"]), label="op")
        if op == "submit":
            t = data.draw(st.sampled_from(tenants), label="tenant")
            plen = data.draw(st.integers(2, 8), label="plen")
            mnew = data.draw(st.integers(1, 6), label="mnew")
            req = Request(rid=rid, tenant=t.name,
                          prompt=np.arange(plen, dtype=np.int32)
                          % max(plen - 1, 1),
                          max_new_tokens=mnew)
            rid += 1
            need = pcfg.pages_for(plen + mnew + 1)
            if need > sched.rm.budget(t.name):
                with pytest.raises(ValueError):
                    sched.submit(req)
                continue
            sched.submit(req)
            submitted.append(req)
        else:
            boundary()

    # drain: every request — including preempted ones — must finish
    for _ in range(400):
        if not sched.has_work:
            break
        boundary()
    assert not sched.has_work
    assert len(sched.finished) == len(submitted)
    for r in submitted:
        assert len(r.tokens) == r.max_new_tokens
    # releasing the retention pins drains the pool completely
    if sched.prefix_cache is not None:
        sched.prefix_cache.release_pins(total)
    assert sched.allocator.n_free == total


_SERVE = {}     # compile cache: one model + one engine per (seg, pool)


def _serve_engine(seg: int, n_pages: int):
    if "model" not in _SERVE:
        from repro.configs.registry import get_config
        from repro.models.api import build_model
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        _SERVE["model"] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    cfg, model, params = _SERVE["model"]
    key = (seg, n_pages)
    if key not in _SERVE:
        from repro.serving import PagedCacheConfig, PagedServingEngine
        pcfg = PagedCacheConfig(page_size=8, n_pages=n_pages,
                                max_slots=2, max_blocks=4,
                                segment_len=seg)
        _SERVE[key] = PagedServingEngine(model, pcfg)
    return cfg, params, _SERVE[key]


@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(2, 12), min_size=2, max_size=4),
       st.sampled_from([2, 4]))
def test_engine_preemption_tokens_bit_identical(gens, seg):
    """Random ragged generation lengths through a pool too small for the
    batch's lifetimes (preempt/restore cycles on most draws) generate
    exactly the tokens of an unconstrained big-pool run, and every
    request finishes."""
    from repro.data.synthetic import lm_tokens
    from repro.serving import Request
    cfg, params, small = _serve_engine(seg, 7)   # 6 pages: lifetimes clash
    _, _, big = _serve_engine(seg, 9)            # 8 pages: fits everything
    prompts = [np.asarray(lm_tokens(16, cfg.vocab_size, seed=40 + i)
                          ).astype(np.int32) for i in range(len(gens))]
    mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                          max_new_tokens=g) for i, g in enumerate(gens)]
    ru, rs = mk(), mk()
    stats_u = big.run(ru, params)
    stats_s = small.run(rs, params)
    assert stats_u["preemptions"] == 0
    assert stats_s["n_finished"] == len(gens)
    assert {r.rid: r.tokens for r in rs} == {r.rid: r.tokens for r in ru}


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 20),
       st.lists(st.integers(2, 10), min_size=2, max_size=4),
       st.data())
def test_engine_chaos_recovers_or_dead_letters(fault_seed, gens, data):
    """Self-healing invariant: a random FaultPlan over a random
    multi-tenant interleaving through a small pool always terminates
    (the watchdog would raise on a hang), never leaks a page, and every
    request either completes with tokens bit-identical to the fault-free
    run or lands dead-lettered with a typed failure record."""
    from repro.data.synthetic import lm_tokens
    from repro.serving import (FaultPlan, PagedCacheConfig,
                               PagedServingEngine, Request, RequestFailed,
                               TenantConfig)
    if "chaos" not in _SERVE:
        _serve_engine(4, 7)                      # populate the model cache
        _, model, _ = _SERVE["model"]
        pcfg = PagedCacheConfig(page_size=8, n_pages=7, max_slots=2,
                                max_blocks=4, segment_len=4)
        _SERVE["chaos"] = PagedServingEngine(
            model, pcfg, tenants=[TenantConfig("a"), TenantConfig("b"),
                                  TenantConfig("c", weight=2.0)])
    cfg, _, params = _SERVE["model"]
    eng = _SERVE["chaos"]
    tenants = [data.draw(st.sampled_from(["a", "b", "c"]),
                         label=f"tenant[{i}]") for i in range(len(gens))]
    prompts = [np.asarray(lm_tokens(16, cfg.vocab_size, seed=40 + i)
                          ).astype(np.int32) for i in range(len(gens))]
    mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                          max_new_tokens=g, tenant=t)
                  for i, (g, t) in enumerate(zip(gens, tenants))]
    base = mk()
    eng.run(base, params)
    want = {r.rid: r.tokens for r in base}
    chaos = mk()
    plan = FaultPlan.seeded(fault_seed, rate=0.2, max_fires=2)
    out = eng.run(chaos, params, faults=plan)
    for r in chaos:
        if r.failure is not None:
            assert isinstance(r.failure, RequestFailed)
        else:
            assert r.tokens == want[r.rid], \
                f"rid {r.rid} diverged after faults {plan.log}"
    assert out["n_finished"] + out["n_dead_lettered"] == len(gens)
    # the pool drains completely: every non-pinned page back on the free
    # list, the ledger intact (quarantine/dead-letter paths leak nothing)
    assert out["free_pages"] + out["pinned_pages"] \
        == eng.pcfg.allocatable_pages
    assert out["held_pages"] == out["pinned_pages"]


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 20),
       st.lists(st.integers(2, 8), min_size=3, max_size=6),
       st.data())
def test_cluster_replica_loss_rejoin_no_request_lost(seed, gens, data):
    """Replicated-serving accounting invariant: random kill/drain/rejoin
    interleavings against a random 2-3-tenant stream through the real
    FrontDoor terminate with every request in exactly one terminal state
    (finished on exactly one replica, or typed-dead-lettered) and every
    surviving replica's pool drained back to free + retention pins."""
    from repro.data.synthetic import lm_tokens
    from repro.serving import (PagedCacheConfig, PagedServingEngine,
                               Request, ServingCluster, TenantConfig)
    if "cluster" not in _SERVE:
        _serve_engine(4, 7)                      # populate the model cache
        _, model, _ = _SERVE["model"]
        pcfg = PagedCacheConfig(page_size=8, n_pages=12, max_slots=2,
                                max_blocks=4, segment_len=4)
        _SERVE["cluster"] = PagedServingEngine(
            model, pcfg, tenants=[TenantConfig("a"), TenantConfig("b"),
                                  TenantConfig("c", weight=2.0)])
    cfg, _, params = _SERVE["model"]
    cl = ServingCluster(_SERVE["cluster"], params, n_replicas=3)
    names = [r.name for r in cl.replicas]
    schedule = {rnd: (data.draw(st.sampled_from(
                          ["none", "kill", "drain", "rejoin"]),
                          label=f"action[{rnd}]"),
                      data.draw(st.sampled_from(names),
                                label=f"target[{rnd}]"))
                for rnd in range(1, 6)}
    tenants = [data.draw(st.sampled_from(["a", "b", "c"]),
                         label=f"tenant[{i}]") for i in range(len(gens))]
    reqs = [Request(rid=i, prompt=np.asarray(
                lm_tokens(16, cfg.vocab_size, seed=40 + i)
            ).astype(np.int32), max_new_tokens=g, tenant=t)
            for i, (g, t) in enumerate(zip(gens, tenants))]

    def on_round(c, rnd):
        action, target = schedule.get(rnd, ("none", ""))
        rep = c._replica(target) if action != "none" else None
        if action == "kill" and rep.live and not rep.crashed:
            c.kill(target)
        elif action == "drain" and rep.live \
                and not (rep.crashed or rep.hung):
            c.drain(target)
        elif action == "rejoin" and not rep.live:
            c.rejoin(target)

    out = cl.run(reqs, on_round=on_round)
    finished = cl.finished
    dead = cl.dead_lettered
    # exactly-once terminal accounting: no request lost, none duplicated
    assert len({r.rid for r in finished}) == len(finished)
    assert {r.rid for r in finished} | {r.rid for r in dead} \
        == {r.rid for r in reqs}
    assert not ({r.rid for r in finished} & {r.rid for r in dead})
    assert out["n_finished"] + out["n_dead_lettered"] == len(reqs)
    for r in reqs:
        assert r.t_done is not None              # every request terminal
        if r.failure is None:
            assert len(r.tokens) == r.max_new_tokens
    # survivor pools drain to full (free + retention pins), ledger intact
    for rep in cl.replicas:
        if rep.fenced:
            continue
        s = rep.run.sched.rm.stats()
        assert s["free_pages"] + s["pinned_pages"] \
            == rep.run.pcfg.allocatable_pages, (rep.name, s)
        assert s["held_pages"] == s["pinned_pages"], (rep.name, s)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 14), st.integers(0, 48),
       st.lists(st.integers(2, 10), min_size=2, max_size=4))
def test_journal_crash_replay_bit_identical_or_dead_letter(
        crash_at, chop, gens):
    """Durability invariant: a journaled run killed at a random boundary
    (or not at all, when the run finishes first), with a random number
    of bytes then chopped off the journal tail, still satisfies the
    restart contract — replay is idempotent, and resuming finishes
    every journal-acknowledged request either bit-identical to the
    uninterrupted run or as a typed dead letter, with the pool drained."""
    import os
    import tempfile

    from repro.data.synthetic import lm_tokens
    from repro.serving import (FaultPlan, JournalWriter, ProcessCrashed,
                               Request, RequestFailed, RestartRecovery,
                               replay_journal)
    cfg, params, eng = _serve_engine(4, 7)
    prompts = [np.asarray(lm_tokens(16, cfg.vocab_size, seed=40 + i)
                          ).astype(np.int32) for i in range(len(gens))]
    mk = lambda: [Request(rid=i, prompt=prompts[i].copy(),  # noqa
                          max_new_tokens=g) for i, g in enumerate(gens)]
    base = mk()
    eng.run(base, params)
    want = {r.rid: r.tokens for r in base}
    with tempfile.TemporaryDirectory() as d:
        w = JournalWriter(d)
        try:
            eng.run(mk(), params, journal=w,
                    faults=FaultPlan.at(process_crash=crash_at))
        except ProcessCrashed:
            pass
        w.close()
        segs = sorted(f for f in os.listdir(d) if f.startswith("wal-"))
        path = os.path.join(d, segs[-1])
        with open(path, "r+b") as f:
            f.truncate(max(0, os.path.getsize(path) - chop))
        assert replay_journal(d).state() == replay_journal(d).state()
        rr = RestartRecovery(d)
        acked = set(rr.replay.requests)
        out = rr.resume(_SERVE["model"][1], params, engine=eng)
        got = {r.rid: r for r in out["requests"]}
        assert set(got) == acked
        for rid, r in got.items():
            if r.failure is not None:
                assert isinstance(r.failure, RequestFailed)
            else:
                assert r.tokens == want[rid], \
                    f"rid {rid} diverged after crash@{crash_at} chop={chop}"
        s = out["stats"]
        assert s["free_pages"] + s["pinned_pages"] \
            == eng.pcfg.allocatable_pages
        # a second replay of the post-resume journal sees every
        # acknowledged request terminal
        rp2 = replay_journal(d)
        assert all(r.status in ("completed", "dead")
                   for r in rp2.requests.values())


# ---------------------------------------------------- binary search props
@SETTINGS
@given(st.floats(0.05, 0.95), st.sampled_from([0.01, 0.02, 0.05]))
def test_binary_search_converges_to_boundary(boundary, beta):
    res = binary_search_max(lambda x: (x <= boundary, x, {}), beta=beta)
    assert res.best_x <= boundary + 1e-9
    assert boundary - res.best_x <= beta + 1e-9


# -------------------------------------------------- SERVE search determinism
@SETTINGS
@given(st.integers(0, 2**31 - 1), st.integers(1, 8),
       st.sampled_from([None, 1.5, 8.0]), st.floats(0.0, 0.9))
def test_serve_search_deterministic_under_fixed_seed(seed, n, rate,
                                                     prefix):
    """The SERVE staged search is a pure function of its seed: the
    TrafficProfile expands to identical request streams, and the staged
    search over the candidate grid — with any deterministic scorer —
    visits identical steps and picks the identical winning plan twice
    over.  (Wall-clock replay noise is the scorer's problem, not the
    search machinery's: given a fixed scorer the emitted plan is
    bit-stable, which is what the deployable-artifact contract needs.)"""
    import json
    import zlib

    from repro.core.search import staged_search
    from repro.serving import ServingPlan, TrafficProfile
    from repro.tasks.serve import candidate_grid

    prof = TrafficProfile(n_requests=n, arrival_rate=rate,
                          prefix_share=prefix, seed=seed)
    a = prof.requests(256, page_size=4)
    b = prof.requests(256, page_size=4)
    assert [(r.arrival, r.tenant) for r in a] \
        == [(r.arrival, r.tenant) for r in b]
    for ra, rb in zip(a, b):
        assert (ra.prompt == rb.prompt).all()

    def scorer(plan, stage):
        key = json.dumps(plan.cache.to_dict(), sort_keys=True)
        crc = zlib.crc32(f"{seed}:{stage}:{key}".encode())
        return crc % 7 != 0, float(crc % 10_000), {}

    grid = candidate_grid(ServingPlan())
    runs = [staged_search(grid, lambda p: scorer(p, 1),
                          lambda p: scorer(p, 2),
                          keep=max(1, len(grid) // 2 - 1),
                          must_keep=(0,))
            for _ in range(2)]
    assert runs[0].best_x == runs[1].best_x
    assert runs[0].best_objective == runs[1].best_objective
    assert [(s.x, s.objective, s.feasible, s.info.get("stage"))
            for s in runs[0].steps] \
        == [(s.x, s.objective, s.feasible, s.info.get("stage"))
            for s in runs[1].steps]
    if runs[0].best_x is not None:
        assert runs[0].best_x.to_dict() == runs[1].best_x.to_dict()


# -------------------------------------------------- gradient compression
@SETTINGS
@given(st.integers(1, 30))
def test_error_feedback_accumulates_exactly(steps):
    """Sum of compressed grads + final residual == sum of true grads
    (the error-feedback invariant that preserves convergence)."""
    rng = np.random.default_rng(steps)
    residual = jnp.zeros((32,), jnp.float32)
    total_true = np.zeros((32,), np.float32)
    total_sent = np.zeros((32,), np.float32)
    for s in range(steps):
        g = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)
        sent, residual = compress_with_feedback(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    np.testing.assert_allclose(total_sent + np.asarray(residual),
                               total_true, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ rope
@SETTINGS
@given(st.integers(1, 8), st.integers(2, 32))
def test_rope_preserves_norm(heads, seq):
    x = jnp.asarray(np.random.default_rng(heads).normal(
        0, 1, (1, seq, heads, 32)), jnp.float32)
    pos = jnp.arange(seq)
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_relative_position_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 64)), jnp.float32)

    def dot_at(i, j):
        qr = apply_rope(q, jnp.asarray([i]))
        kr = apply_rope(k, jnp.asarray([j]))
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-3
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-3
