"""Serving telemetry layer: MetricsRegistry semantics (typed handles,
label filtering, snapshot/delta, Prometheus rendering against golden
files), request-lifecycle tracing (span trees, seeded-chaos determinism),
the disabled-mode zero-allocation guarantee, and the stats() thin-view
consolidation (historical counters must read back identical through the
registry)."""

import gc
import json
import os
import sys

import numpy as np
import jax
import pytest

from repro.serving import (FaultPlan, MetricsRegistry, NULL_METRIC,
                           Observability, ObservabilityPolicy,
                           PagedCacheConfig, PagedServingEngine,
                           RecoveryPolicy, Request, ServingPlan,
                           TenantConfig, Tracer, exponential_buckets,
                           render_summary)
from repro.serving.observe import Counter, Gauge, Histogram

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


# ------------------------------------------------------------- registry
class TestRegistry:
    def test_counter_inc_and_label_filtering(self):
        reg = MetricsRegistry()
        c = reg.counter("c", "help", ("replica", "tenant"))
        c.inc(2.0, ("r0", "a"))
        c.inc(1.0, ("r0", "b"))
        c.inc(4.0, ("r1", "a"))
        assert c.value(("r0", "a")) == 2.0
        assert c.total() == 7.0
        assert c.total(replica="r0") == 3.0
        assert c.total(tenant="a") == 6.0
        assert c.total(replica="r1", tenant="a") == 4.0
        with pytest.raises(ValueError):
            c.total(site="x")                  # unknown label name
        with pytest.raises(ValueError):
            c.inc(-1.0)                        # counters are monotonic

    def test_handles_idempotent_and_typed(self):
        reg = MetricsRegistry()
        a = reg.counter("m", "h", ("x",))
        assert reg.counter("m", "h", ("x",)) is a
        with pytest.raises(ValueError):
            reg.gauge("m", "h", ("x",))        # kind mismatch
        with pytest.raises(ValueError):
            reg.counter("m", "h", ("y",))      # label mismatch

    def test_gauge_set_inc_dec(self):
        g = Gauge("g", labels=("r",))
        g.set(5, ("r0",))
        g.inc(2, ("r0",))
        g.dec(3, ("r0",))
        assert g.value(("r0",)) == 4.0

    def test_snapshot_delta(self):
        reg = MetricsRegistry()
        c = reg.counter("c")
        h = reg.histogram("h", buckets=(1.0, 2.0))
        c.inc(3.0)
        h.observe(0.5)
        prev = json.loads(json.dumps(reg.snapshot()))  # JSON-safe
        c.inc(2.0)
        h.observe(1.5)
        d = reg.delta(prev)
        assert d["c"]["series"][0]["value"] == 2.0
        hs = d["h"]["series"][0]
        assert hs["count"] == 1 and hs["counts"] == [0, 1, 0]
        assert hs["sum"] == 1.5

    def test_exponential_buckets_validation(self):
        b = exponential_buckets(0.001, 2.0, 4)
        assert b == (0.001, 0.002, 0.004, 0.008)
        for bad in ((0, 2.0, 4), (0.1, 1.0, 4), (0.1, 2.0, 0)):
            with pytest.raises(ValueError):
                exponential_buckets(*bad)
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))  # not increasing
        with pytest.raises(ValueError):
            Histogram("h", buckets=())


class TestHistogram:
    def test_le_semantics_and_percentile(self):
        h = Histogram("h", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 3.0, 100.0):
            h.observe(v)
        counts, total, n = h.series[()]
        # le semantics: v == bound lands in that bound's bucket
        assert counts == [2, 1, 1, 1]
        assert n == 5 and total == 106.0
        assert h.count(()) == 5
        # past the top finite bound clamps to it
        assert h.percentile(100) == 4.0
        assert 0.0 < h.percentile(50) <= 2.0
        assert h.percentile(50, labels=()) == h.percentile(50)

    def test_empty_percentile_is_zero(self):
        h = Histogram("h", buckets=(1.0,))
        assert h.percentile(95) == 0.0

    def test_bucket_invariants_property(self):
        """sum(counts) == count, cumulative counts are monotone, the
        +Inf slot catches everything past the top bound, and sum tracks
        the observed values exactly."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=50, deadline=None)
        @given(st.lists(st.floats(min_value=0.0, max_value=1e6,
                                  allow_nan=False, allow_infinity=False),
                        max_size=100))
        def prop(values):
            h = Histogram("h", buckets=exponential_buckets(1e-3, 4.0, 8))
            for v in values:
                h.observe(v)
            if not values:
                assert h.series == {}
                return
            counts, total, n = h.series[()]
            assert len(counts) == len(h.buckets) + 1
            assert sum(counts) == n == len(values)
            cum = np.cumsum(counts)
            assert all(np.diff(cum) >= 0)
            assert counts[-1] == sum(1 for v in values
                                     if v > h.buckets[-1])
            assert total == pytest.approx(sum(values), rel=1e-9, abs=1e-12)
        prop()


# ------------------------------------------------------------ exporters
class TestExporters:
    def _golden_registry(self):
        reg = MetricsRegistry(histogram_buckets=(0.001, 0.01, 0.1))
        c = reg.counter("serving_admitted_total", "requests admitted",
                        ("replica", "tenant"))
        c.inc(3.0, ("r0", "svc"))
        c.inc(1.0, ("r0", "batch"))
        g = reg.gauge("serving_pool_free_pages", "free pages",
                      ("replica",))
        g.set(11, ("r0",))
        h = reg.histogram("serving_ttft_seconds",
                          "submit to first token", ("replica",))
        for v in (0.0005, 0.002, 0.02, 0.2):
            h.observe(v, ("r0",))
        return reg

    def _golden_tracer(self):
        t = Tracer()
        t.event(7, "SUBMIT", 0, 0.0, tenant="svc", prompt_len=32,
                max_new=16)
        t.event(7, "ADMIT", 1, 0.25, restore=False, slot=0, pages=3,
                shared_tokens=0)
        t.event(7, "SEGMENT", 1, 0.5, tokens=4)
        t.event(7, "COMPLETE", 2, 0.75, n_tokens=16, preemptions=0,
                retries=0)
        return t

    def test_prometheus_golden(self):
        got = self._golden_registry().to_prometheus()
        with open(os.path.join(GOLDEN, "metrics.prom")) as f:
            assert got == f.read()

    def test_jsonl_golden(self, tmp_path):
        path = self._golden_tracer().to_jsonl(
            str(tmp_path / "trace.jsonl"))
        with open(path) as f, \
                open(os.path.join(GOLDEN, "trace.jsonl")) as g:
            assert f.read() == g.read()

    def test_render_summary_shape(self):
        s = render_summary(self._golden_registry())
        assert s["counters"]["serving_admitted_total"] == 4.0
        assert s["gauges"]["serving_pool_free_pages"] == 11.0
        hs = s["histograms"]["serving_ttft_seconds"]
        assert hs["count"] == 4
        assert hs["mean"] == pytest.approx(0.2225 / 4)
        assert 0.0 < hs["p50"] <= hs["p95"] <= 0.1


# --------------------------------------------------------------- tracer
class TestTracer:
    def test_span_tree_groups_lifecycle(self):
        t = Tracer()
        t.event(1, "SUBMIT", 0, 0.0)
        t.event(1, "ADMIT", 1, 0.1, restore=False)
        t.event(1, "SEGMENT", 1, 0.2, tokens=4)
        t.event(1, "PREEMPT", 2, 0.3, by=2)
        t.event(1, "ADMIT", 3, 0.4, restore=True)
        t.event(1, "COMPLETE", 4, 0.5)
        t.event(2, "SUBMIT", 0, 0.0)           # other rid: filtered out
        spans = t.span_tree(1)
        assert [s["phase"] for s in spans] == \
            ["queued", "running", "swapped", "running", "done"]
        assert spans[1]["events"] == ["ADMIT", "SEGMENT"]
        assert spans[1]["t_end"] == 0.3        # closed by the PREEMPT
        assert t.rids() == [1, 2]

    def test_sequence_drops_timestamps_only(self):
        a, b = Tracer(), Tracer()
        a.event(1, "SUBMIT", 0, 0.123, tenant="x")
        b.event(1, "SUBMIT", 0, 9.876, tenant="x")
        assert a.sequence() == b.sequence()
        b.event(1, "ADMIT", 1, 0.0)
        assert a.sequence() != b.sequence()


# -------------------------------------------------- facade + plan knobs
class TestObservability:
    def test_disabled_handles(self):
        obs = Observability.disabled()
        assert not obs.enabled and obs.tracer is None
        assert obs.histogram("h") is NULL_METRIC
        assert obs.gauge("g") is NULL_METRIC
        # counters stay real: they back the stats() thin views
        c = obs.counter("c", "", ("x",))
        assert isinstance(c, Counter)
        # never a singleton: independent stores
        assert Observability.disabled().registry is not obs.registry

    def test_disabled_probe_allocates_nothing(self):
        """The disabled hot path: a no-op call against NULL_METRIC must
        not allocate (one attribute lookup + call, nothing else)."""
        observe = NULL_METRIC.observe
        for _ in range(64):
            observe(1.0, ("r0",))              # warm any caches
        gc.collect()
        before = sys.getallocatedblocks()
        for _ in range(10_000):
            observe(1.0, ("r0",))
        grown = sys.getallocatedblocks() - before
        assert grown <= 2, f"disabled probe allocated {grown} blocks"

    def test_for_replica_shares_store(self):
        pol = ObservabilityPolicy(enabled=True)
        obs = Observability.from_policy(pol)
        r0, r1 = obs.for_replica("r0"), obs.for_replica("r1")
        assert r0.registry is r1.registry is obs.registry
        assert r0.tracer is obs.tracer
        c0 = r0.counter("c", "", ("replica",))
        c0.inc(1.0, (r0.replica,))
        r1.counter("c", "", ("replica",)).inc(2.0, (r1.replica,))
        assert c0.total() == 3.0
        assert c0.total(replica="r1") == 2.0

    def test_policy_validation_and_plan_round_trip(self, tmp_path):
        with pytest.raises(ValueError):
            ObservabilityPolicy(histogram_buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            ObservabilityPolicy(enabled=False, export_dir="/tmp/x")
        plan = ServingPlan(
            cache=PagedCacheConfig(page_size=8, n_pages=16, max_slots=2,
                                   max_blocks=4, segment_len=4),
            observability=ObservabilityPolicy(
                enabled=True, histogram_buckets=(0.01, 0.1)))
        back = ServingPlan.from_dict(
            json.loads(json.dumps(plan.to_dict())))
        assert back == plan
        assert back.observability.histogram_buckets == (0.01, 0.1)
        # resolve() provenance distinguishes defaulted from explicit
        from repro.configs.registry import get_config
        cfg = get_config("qwen2_7b", smoke=True)
        cold = str(tmp_path / "empty_cache.json")
        pol = ObservabilityPolicy(enabled=True)
        p2 = ServingPlan.resolve(cfg, slots=2, max_prompt_len=16,
                                 max_new_tokens=8, cache_path=cold,
                                 observability=pol)
        assert p2.provenance["observability"] == "explicit"
        assert p2.observability is pol
        p3 = ServingPlan.resolve(cfg, slots=2, max_prompt_len=16,
                                 max_new_tokens=8, cache_path=cold)
        assert p3.provenance["observability"] == "default"
        assert not p3.observability.enabled


# ----------------------------------------------------------- end to end
_E = {}


def _engine_fixture():
    if not _E:
        from repro.configs.registry import get_config
        from repro.models.api import build_model
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        pcfg = PagedCacheConfig(page_size=8, n_pages=24, max_slots=4,
                                max_blocks=6, segment_len=4,
                                retain_pages=4)
        eng = PagedServingEngine(
            model, pcfg, tenants=[TenantConfig("a"), TenantConfig("b")])
        _E["x"] = (cfg, model.init(jax.random.PRNGKey(0)), eng)
    return _E["x"]


def _mk_reqs(cfg, n=6, gen=12):
    from repro.data.synthetic import lm_tokens
    return [Request(rid=i, prompt=np.asarray(
                lm_tokens(16, cfg.vocab_size, seed=70 + i)
            ).astype(np.int32), max_new_tokens=gen,
            tenant="a" if i % 2 else "b") for i in range(n)]


def _chaos_run(cfg, params, eng, out_dir=""):
    obs = Observability.from_policy(ObservabilityPolicy(enabled=True))
    reqs = _mk_reqs(cfg)
    stats = eng.run(reqs, params,
                    faults=FaultPlan.at(alloc=1, decode_poison=1),
                    recovery=RecoveryPolicy(check_invariants=True),
                    obs=obs)
    if out_dir:
        stats["exports"] = obs.export(out_dir)
    return obs, reqs, stats


def test_run_emits_request_records_and_metrics():
    cfg, params, eng = _engine_fixture()
    obs = Observability.from_policy(ObservabilityPolicy(enabled=True))
    reqs = _mk_reqs(cfg)
    stats = eng.run(reqs, params, obs=obs)
    recs = {r["rid"]: r for r in stats["requests"]}
    assert set(recs) == {r.rid for r in reqs}
    for req in reqs:
        rec = recs[req.rid]
        assert not rec["dead"]
        assert rec["e2e_s"] == pytest.approx(req.t_done - req.arrival)
        assert 0.0 <= rec["ttft_s"] <= rec["e2e_s"]
        assert rec["n_tokens"] == len(req.tokens)
    m = stats["metrics"]
    assert m["counters"]["serving_admitted_total"] == len(reqs)
    assert m["histograms"]["serving_e2e_latency_seconds"]["count"] \
        == len(reqs)
    # the tracer saw the full lifecycle of every request
    for req in reqs:
        kinds = [e.kind for e in obs.tracer.trace(req.rid)]
        assert kinds[0] == "SUBMIT" and kinds[-1] == "COMPLETE"
        assert "ADMIT" in kinds and "SEGMENT" in kinds


def test_stats_views_match_registry():
    """The consolidation invariant: the historical stats() dict keys are
    thin views over registry counters — one storage, two reads."""
    cfg, params, eng = _engine_fixture()
    obs, reqs, stats = _chaos_run(cfg, params, eng)
    by_name = {m.name: m for m in obs.registry.metrics()}
    rm_keys = {
        "preemptions": "serving_preemptions_total",
        "restores": "serving_restores_total",
        "pages_swapped_out": "serving_pages_swapped_out_total",
        "pages_swapped_in": "serving_pages_swapped_in_total",
        "dead_letters": "serving_dead_letters_total",
    }
    for key, metric in rm_keys.items():
        assert stats[key] == int(by_name[metric].total()), key
    rec = stats["recovery"]
    assert rec["quarantines"] == \
        int(by_name["serving_quarantines_total"].total())
    assert stats["faults"] is not None
    fired = by_name["serving_fault_fires_total"]
    for site, _ in stats["faults"]["fired"]:
        assert fired.total(site=site) >= 1


def test_seeded_chaos_trace_is_deterministic():
    """Two identical seeded chaos runs produce bit-equal trace
    sequences (timestamps excluded) and bit-equal tokens."""
    cfg, params, eng = _engine_fixture()
    obs_a, reqs_a, _ = _chaos_run(cfg, params, eng)
    obs_b, reqs_b, _ = _chaos_run(cfg, params, eng)
    assert obs_a.tracer.sequence() == obs_b.tracer.sequence()
    assert {r.rid: list(r.tokens) for r in reqs_a} \
        == {r.rid: list(r.tokens) for r in reqs_b}
    # the decode_poison fire is attributable: a QUARANTINE span event
    # names the site and a real rid
    quar = [e for e in obs_a.tracer.events if e.kind == "QUARANTINE"
            and e.detail.get("site") == "decode_poison"]
    assert quar and all(e.rid is not None for e in quar)


def test_export_files_and_plan_export_dir(tmp_path):
    cfg, params, eng = _engine_fixture()
    _, _, stats = _chaos_run(cfg, params, eng, out_dir=str(tmp_path))
    paths = stats["exports"]
    with open(paths["metrics"]) as f:
        prom = f.read()
    assert "# TYPE serving_admitted_total counter" in prom
    assert "serving_ttft_seconds_bucket" in prom
    with open(paths["trace"]) as f:
        events = [json.loads(line) for line in f]
    assert events and {"rid", "kind", "boundary", "t", "detail"} \
        <= set(events[0])
    assert any(e["kind"] == "FAULT" for e in events)
