"""Sharding rules + a small-mesh end-to-end lower/compile (the dry-run
machinery at 8 fake devices, run in a subprocess so the main test process
keeps its single real CPU device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import ShardingRules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh_1d():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


class TestShardingRules:
    def test_divisible_dims_shard(self):
        rules = ShardingRules.default(_mesh_1d())
        spec = rules.spec_for(("vocab", "embed"), (1024, 64))
        assert spec == P("model")

    def test_non_divisible_falls_back(self):
        # fake a 16-wide model axis via a mesh-shaped rules check
        mesh = _mesh_1d()
        rules = ShardingRules.default(mesh)
        # axis size 1 always divides; simulate via explicit spec on dims
        spec = rules.spec_for(("heads",), (28,))
        assert spec in (P("model"), P())  # 1-device: divides trivially

    def test_axis_not_reused(self):
        rules = ShardingRules.default(_mesh_1d())
        spec = rules.spec_for(("cache_seq", "kv_heads"), (64, 8))
        entries = [e for e in spec if e is not None]
        assert len(entries) == len(set(entries))  # no mesh axis twice

    def test_overrides(self):
        rules = ShardingRules.default(_mesh_1d(),
                                      overrides={"cache_seq": None})
        assert rules.spec_for(("cache_seq",), (64,)) == P()


SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.configs.base import ShapeSpec
    from repro.models.api import build_model
    from repro.optim.optimizers import adamw
    from repro.parallel.sharding import ShardingRules
    from repro.runtime.train_loop import (batch_shardings, cache_shardings,
                                          make_decode_step, make_train_step,
                                          state_shardings,
                                          init_train_state)

    results = {}
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    for arch in ["qwen2_7b", "granite_moe_1b_a400m", "zamba2_2p7b"]:
        cfg = get_config(arch, smoke=True).replace(remat="dots")
        model = build_model(cfg, mesh=mesh)
        rules = ShardingRules.default(mesh)
        opt = adamw(1e-3)
        step = make_train_step(model, opt)
        with mesh:
            sshard = state_shardings(model, rules, "adamw")
            state_abs = jax.eval_shape(
                lambda k: init_train_state(model, opt, k),
                jax.random.PRNGKey(0))
            specs = model.input_specs(ShapeSpec("t", 64, 8, "train"))
            bshard = batch_shardings(model, rules, specs)
            lowered = jax.jit(step, in_shardings=(sshard, bshard)
                              ).lower(state_abs, specs)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            results[arch + ":train"] = float(
                (ca[0] if isinstance(ca, (list, tuple)) else ca)
                .get("flops", -1))
            # decode path too
            dstep = make_decode_step(model)
            cache_abs, _ = model.abstract_cache(8, 64)
            cshard = cache_shardings(model, rules, 8, 64)
            pshard = sshard["params"]
            dl = jax.jit(dstep, in_shardings=(
                pshard, cshard,
                rules.sharding_for(("batch", None), (8, 1)))).lower(
                model.abstract_params(), cache_abs,
                jax.ShapeDtypeStruct((8, 1), jnp.int32))
            dl.compile()
            results[arch + ":decode"] = "ok"
    print("RESULT " + json.dumps(results))
""")


@pytest.mark.slow
def test_small_mesh_lower_compile_multi_arch():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    assert results["qwen2_7b:decode"] == "ok"
    assert results["granite_moe_1b_a400m:decode"] == "ok"
    assert results["zamba2_2p7b:decode"] == "ok"
    assert all(v != -1 for k, v in results.items() if k.endswith("train"))


@pytest.mark.slow
def test_moe_ep_matches_dense_reference_on_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
        from repro.configs.registry import get_config
        from repro.models import layers as L
        from repro.models.common import Ctx
        cfg = get_config("granite_moe_1b_a400m", smoke=True)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        p, _ = L.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, 16, cfg.d_model)) * 0.5
        ref = L._moe_dense_reference(Ctx(), cfg, p, x)
        with mesh:
            y = jax.jit(lambda x: L._moe_ep(Ctx(mesh=mesh), cfg, p, x))(x)
        err = float(jnp.max(jnp.abs(y - ref)))
        # decode-sized path
        x2 = jax.random.normal(jax.random.PRNGKey(2),
                               (2, 1, cfg.d_model)) * 0.5
        ref2 = L._moe_dense_reference(Ctx(), cfg, p, x2)
        with mesh:
            y2 = jax.jit(lambda x: L._moe_ep(Ctx(mesh=mesh), cfg, p,
                                             x))(x2)
        err2 = float(jnp.max(jnp.abs(y2 - ref2)))
        print(f"RESULT {err} {err2}")
    """)
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    err, err2 = (float(t) for t in line.split()[1:])
    assert err < 5e-3   # bf16 expert FFN vs f32 reference
    assert err2 < 5e-3
