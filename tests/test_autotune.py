"""Autotuner: cache round-trip, constraint pruning, tuned-config
equivalence, and the TUNE O-task's SearchStep trace."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metamodel import MetaModel
from repro.core.search import exhaustive_search
from repro.kernels import autotune, ref
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.sparsity.masks import block_map, block_mask

KEY = jax.random.PRNGKey(0)
QMM_PROBLEM = autotune.quant_matmul_problem((128, 256), (256, 128),
                                            "float32")


@pytest.fixture
def cache_path(tmp_path):
    autotune.clear_memory_cache()
    yield str(tmp_path / "autotune.json")
    autotune.clear_memory_cache()


def fake_timer(schedule):
    """Timer returning scripted µs per config (no kernels executed)."""
    calls = []

    def timer(fn, *, warmup, iters):
        calls.append(fn)
        return schedule(len(calls))

    timer.calls = calls
    return timer


class TestCache:
    def test_roundtrip_second_call_hits_disk(self, cache_path):
        timer = fake_timer(lambda n: 100.0 + n)
        res = autotune.tune("quant_matmul", QMM_PROBLEM,
                            cache_path=cache_path, timer=timer,
                            max_trials=4)
        assert not res.cached and len(timer.calls) == 4
        # winner is the first (lowest scripted time) candidate
        assert res.us == 101.0

        # same process: in-memory hit, timer untouched
        res2 = autotune.tune("quant_matmul", QMM_PROBLEM,
                             cache_path=cache_path, timer=timer,
                             max_trials=4)
        assert res2.cached and res2.config == res.config
        assert len(timer.calls) == 4

        # fresh process (memory cache dropped): disk hit, no re-measure
        autotune.clear_memory_cache()
        res3 = autotune.tune("quant_matmul", QMM_PROBLEM,
                             cache_path=cache_path, timer=timer,
                             max_trials=4)
        assert res3.cached and res3.config == res.config
        assert len(timer.calls) == 4

    def test_cache_file_format(self, cache_path):
        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=fake_timer(lambda n: float(n)), max_trials=2)
        with open(cache_path) as f:
            data = json.load(f)
        assert data["version"] == autotune.CACHE_VERSION
        key = autotune.cache_key("quant_matmul", QMM_PROBLEM)
        entry = data["entries"][key]
        assert set(entry) >= {"config", "us", "n_trials", "backend"}

    def test_force_remeasures(self, cache_path):
        timer = fake_timer(lambda n: float(n))
        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=timer, max_trials=2)
        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=timer, max_trials=2, force=True)
        assert len(timer.calls) == 4

    def test_deeper_search_refreshes_shallow_entry(self, cache_path):
        timer = fake_timer(lambda n: float(n))
        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=timer, max_trials=2)
        # same depth: hit; deeper request: the shallow entry is not
        # evidence, so the search re-runs and overwrites
        hit = autotune.tune("quant_matmul", QMM_PROBLEM,
                            cache_path=cache_path, timer=timer,
                            max_trials=2)
        assert hit.cached and len(timer.calls) == 2
        deep = autotune.tune("quant_matmul", QMM_PROBLEM,
                             cache_path=cache_path, timer=timer,
                             max_trials=6)
        assert not deep.cached and len(timer.calls) == 8
        # and the refreshed (deeper) entry now serves shallow requests
        again = autotune.tune("quant_matmul", QMM_PROBLEM,
                              cache_path=cache_path, timer=timer,
                              max_trials=2)
        assert again.cached and len(timer.calls) == 8

    def test_other_backend_entry_is_a_miss(self, cache_path):
        timer = fake_timer(lambda n: float(n))
        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=timer, max_trials=2)
        data = json.load(open(cache_path))
        key = autotune.cache_key("quant_matmul", QMM_PROBLEM)
        data["entries"][key]["backend"] = "tpu"   # tuned elsewhere
        with open(cache_path, "w") as f:
            json.dump(data, f)
        autotune.clear_memory_cache()
        res = autotune.tune("quant_matmul", QMM_PROBLEM,
                            cache_path=cache_path, timer=timer,
                            max_trials=2)
        assert not res.cached and len(timer.calls) == 4  # re-measured

    def test_distinct_problems_distinct_keys(self):
        other = autotune.quant_matmul_problem((128, 256), (256, 128),
                                              "bfloat16")
        assert (autotune.cache_key("quant_matmul", QMM_PROBLEM)
                != autotune.cache_key("quant_matmul", other))


class TestConstraintPruning:
    def test_all_candidates_within_budget(self):
        budget = 300_000
        for kernel, problem in [
            ("quant_matmul", QMM_PROBLEM),
            ("flash_attention", autotune.flash_attention_problem(
                (1, 256, 2, 64), (1, 256, 2, 64), "float32")),
            ("block_sparse_matmul", autotune.block_sparse_matmul_problem(
                (256, 512), (512, 512), "float32", max_live=4)),
        ]:
            cands = autotune.enumerate_candidates(kernel, problem,
                                                  vmem_budget=budget)
            assert cands, kernel
            assert all(v <= budget for _, v in cands), kernel

    def test_over_budget_candidate_never_timed(self, cache_path):
        budget = 200_000  # prunes the largest (bm, bn, bk) combinations
        timed = []

        def timer(fn, *, warmup, iters):
            timed.append(fn)
            return 1.0

        autotune.tune("quant_matmul", QMM_PROBLEM, cache_path=cache_path,
                      timer=timer, vmem_budget=budget, max_trials=None)
        allowed = len(autotune.enumerate_candidates(
            "quant_matmul", QMM_PROBLEM, vmem_budget=budget))
        full = len(autotune.enumerate_candidates(
            "quant_matmul", QMM_PROBLEM, vmem_budget=2 ** 60))
        assert len(timed) == allowed < full

    def test_divisibility_pruning(self):
        # n=384 is not divisible by 256: no candidate may use block_n=256
        prob = autotune.quant_matmul_problem((128, 512), (512, 384),
                                             "float32")
        cands = autotune.enumerate_candidates("quant_matmul", prob)
        assert all(c["block_n"] != 256 for c, _ in cands)

    def test_no_feasible_candidate_raises(self, cache_path):
        with pytest.raises(ValueError):
            autotune.tune("quant_matmul", QMM_PROBLEM,
                          cache_path=cache_path, vmem_budget=1)

    def test_small_dims_keep_literal_default_config(self):
        # dims < 128 clamp several nominal tiles together; the surviving
        # representative must be the literal default so default_us exists
        prob = autotune.flash_attention_problem((1, 64, 2, 32),
                                                (1, 64, 2, 32), "float32")
        cands = autotune.enumerate_candidates("flash_attention", prob)
        assert {"block_q": 128, "block_kv": 128} in [c for c, _ in cands]

    def test_default_config_survives_trial_cap(self):
        prob = autotune.quant_matmul_problem((512, 1024), (1024, 512),
                                             "float32")
        cands = autotune.enumerate_candidates("quant_matmul", prob,
                                              max_trials=4)
        assert cands[0][0] == autotune.KERNELS["quant_matmul"].default_config


class TestTunedConfigEquivalence:
    """Non-default tile configs still match the kernels/ref.py oracles."""

    @pytest.mark.parametrize("cfg", [dict(block_m=64, block_n=64,
                                          block_k=128),
                                     dict(block_m=32, block_n=256,
                                          block_k=64)])
    def test_quant_matmul(self, cfg):
        x = jax.random.normal(KEY, (128, 512))
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
        y = quant_matmul(x, w, interpret=True, **cfg)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.quant_matmul_ref(x, w)),
            rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("cfg", [dict(block_q=64, block_kv=32),
                                     dict(block_q=32, block_kv=128)])
    @pytest.mark.parametrize("kv_heads", [1, 2])
    def test_flash_attention(self, cfg, kv_heads):
        b, s, h, d = 1, 192, 4, 32
        q = jax.random.normal(KEY, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv_heads, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv_heads, d))
        y = flash_attention(q, k, v, causal=True, interpret=True, **cfg)
        r = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("block_m", [32, 64])
    def test_block_sparse_matmul(self, block_m):
        m, k, n = 256, 512, 384
        x = jax.random.normal(KEY, (m, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        mask = block_mask(w, rate=0.5, block=128)
        wm = w * mask
        kidx = jnp.asarray(compact_block_index(
            block_map(np.asarray(mask), 128)))
        y = block_sparse_matmul(x, wm, kidx, block_m=block_m,
                                interpret=True)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.block_sparse_matmul_ref(x, wm)),
            rtol=1e-4, atol=1e-3)

    def test_tuned_dispatcher_matches_ref(self, cache_path):
        x = jax.random.normal(KEY, (128, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
        y = autotune.tuned_quant_matmul(x, w, interpret=True,
                                        cache_path=cache_path,
                                        max_trials=2, iters=1)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.quant_matmul_ref(x, w)),
            rtol=1e-4, atol=1e-3)


class TestExhaustiveSearch:
    def test_picks_max_objective_with_trace(self):
        seen = []

        def evaluate(x):
            seen.append(x)
            return x != 3, -abs(x - 3), {}

        res = exhaustive_search([1, 2, 3, 4], evaluate)
        assert res.best_x == 2 and seen == [1, 2, 3, 4]
        assert [s.step for s in res.steps] == [1, 2, 3, 4]


class TestTuneTask:
    def test_flow_records_searchsteps(self, cache_path):
        from repro.core.flow import DesignFlow
        from repro.tasks.model_gen import ModelGen
        from repro.tasks.tune import Tune

        flow = DesignFlow("tune-test")
        flow.chain(ModelGen(model="jet_dnn", train_en=False),
                   Tune(max_trials=2, iters=1, max_problems=1,
                        cache_path=cache_path))
        meta = flow.execute(MetaModel())
        probes = meta.trace("tune.probe")
        assert len(probes) == 2          # one SearchStep per measured config
        assert all("config" in p and "us" in p for p in probes)
        art = meta.latest("dnn")
        assert art.name.endswith("+T#2")
        configs = art.payload.meta["tile_configs"]
        assert configs and meta.get("tune.result")["configs"] == configs
        assert art.metrics["tune.search_steps"] == 2

        # second execution: cache hit -> single cached probe step
        flow2 = DesignFlow("tune-test-2")
        flow2.chain(ModelGen(model="jet_dnn", train_en=False),
                    Tune(max_trials=2, iters=1, max_problems=1,
                         cache_path=cache_path))
        meta2 = flow2.execute(MetaModel())
        probes2 = meta2.trace("tune.probe")
        assert len(probes2) == 1 and probes2[0].get("cached")

    def test_derive_problems_lm(self, cache_path):
        from repro.tasks.tune import derive_problems
        from repro.tasks.handle import DNNHandle

        class _Cfg:
            n_heads, n_kv_heads, d_model, head_dim = 4, 2, 128, 0

            @property
            def hd(self):
                return 32

        class _Model:
            cfg = _Cfg()

        handle = DNNHandle(kind="lm", name="toy",
                           params={"w": jnp.zeros((128, 128))},
                           model=_Model())
        probs = derive_problems(handle, max_problems=4)
        kernels = {p["kernel"] for p in probs}
        assert "flash_attention" in kernels
        fa = next(p for p in probs if p["kernel"] == "flash_attention")
        assert fa["kv_heads"] == 2 and fa["h"] == 4
