"""flash_decode Pallas kernel vs the jnp decode oracle, the shared
cache-position helper, and scan-vs-Python-loop generate equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.flash_decode import flash_decode
from repro.models import layers as L
from repro.models.common import Ctx

KEY = jax.random.PRNGKey(0)


def decode_oracle(q, k, v, mask):
    """The jnp one-token attention math from layers.attention's decode
    branch (expanded K/V + masked softmax)."""
    h = q.shape[2]
    k_exp = L._expand_kv(k, h)
    v_exp = L._expand_kv(v, h)
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k_exp.astype(jnp.float32))
    s = jnp.where(mask[None, None, None, :], s, L.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v_exp.astype(jnp.float32)).astype(q.dtype)


def _qkv(b, cache_len, h, kv_heads, d, dtype=jnp.float32):
    q = jax.random.normal(KEY, (b, 1, h, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1),
                          (b, cache_len, kv_heads, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2),
                          (b, cache_len, kv_heads, d)).astype(dtype)
    return q, k, v


class TestFlashDecodeKernel:
    @pytest.mark.parametrize("kv_heads", [1, 2, 4])
    def test_gqa_group_sizes(self, kv_heads):
        q, k, v = _qkv(2, 64, 4, kv_heads, 32)
        mask = jnp.arange(64) < 40
        y = flash_decode(q, k, v, mask, interpret=True)
        r = decode_oracle(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("fill", [1, 17, 64])   # pos=0 / mid / full
    def test_fill_levels(self, fill):
        cache_len = 64
        q, k, v = _qkv(2, cache_len, 4, 2, 16)
        mask = jnp.arange(cache_len) < fill
        y = flash_decode(q, k, v, mask, interpret=True)
        r = decode_oracle(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("block_kv", [32, 64, 128, 256])
    def test_kv_split_configs(self, block_kv):
        """Non-default kv splits: genuine multi-tile reductions (128/32 =
        4 partial-softmax steps) and tiles larger than the cache."""
        cache_len = 128
        q, k, v = _qkv(1, cache_len, 8, 2, 16)
        mask = jnp.arange(cache_len) < 77
        y = flash_decode(q, k, v, mask, interpret=True, block_kv=block_kv)
        r = decode_oracle(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    def test_ragged_cache_snaps_divisor_safe(self):
        """A split that does not divide the cache snaps (pick_block_kv)
        rather than padding a cache copy every step — and stays exact."""
        from repro.kernels.flash_decode import pick_block_kv
        assert pick_block_kv(32, 100) == 100        # ragged -> one tile
        assert pick_block_kv(32, 128) == 32         # divisor kept
        assert pick_block_kv(128, 49) == 49         # clamp is exact
        assert pick_block_kv(None, 4096) == 128
        cache_len = 100
        q, k, v = _qkv(1, cache_len, 4, 2, 16)
        mask = jnp.arange(cache_len) < 77
        y = flash_decode(q, k, v, mask, interpret=True, block_kv=32)
        r = decode_oracle(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    def test_ring_buffer_mask(self):
        """Wrapped sliding-window mask (live slots non-contiguous across
        the ring seam) matches the oracle."""
        cache_len, window, pos = 32, 24, 45     # wrapped: 45 % 32 = 13
        q, k, v = _qkv(2, cache_len, 4, 2, 16)
        kv_pos = L.kv_positions_for_cache(jnp.asarray(pos), cache_len,
                                          window)
        mask = L.decode_attention_mask(kv_pos, pos, window)
        y = flash_decode(q, k, v, mask, interpret=True, block_kv=32)
        r = decode_oracle(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = _qkv(1, 48, 4, 1, 32, dtype)
        mask = jnp.arange(48) < 48
        y = flash_decode(q, k, v, mask, interpret=True)
        assert y.dtype == dtype
        r = decode_oracle(q, k, v, mask)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol)


class TestCachedConfig:
    def test_layer_dispatch_sees_persisted_winner(self, tmp_path):
        """cached_config: default on a miss, the persisted TUNE winner on
        a hit — and never triggers a tile search itself."""
        from repro.kernels import autotune
        path = str(tmp_path / "cache.json")
        prob = autotune.flash_decode_problem((1, 1, 4, 16), (1, 64, 2, 16),
                                             "float32")
        assert autotune.cached_config("flash_decode", prob,
                                      cache_path=path) == {"block_kv": 128}
        res = autotune.tune("flash_decode", prob, cache_path=path,
                            iters=1, max_trials=3)
        autotune.clear_memory_cache()
        assert autotune.cached_config("flash_decode", prob,
                                      cache_path=path) == res.config

    def test_relaxed_match_covers_serving_shapes(self, tmp_path):
        """A TUNE entry at the arch's nominal (b, cache_len) stands in
        for the serving shape's actual batch/cache length via relax."""
        from repro.kernels import autotune
        path = str(tmp_path / "cache.json")
        tuned_prob = autotune.flash_decode_problem(
            (4, 1, 4, 16), (4, 256, 2, 16), "float32")
        res = autotune.tune("flash_decode", tuned_prob, cache_path=path,
                            iters=1, max_trials=3)
        serve_prob = autotune.flash_decode_problem(
            (2, 1, 4, 16), (2, 49, 2, 16), "float32")
        # strict lookup misses; relaxed lookup finds the tuned entry
        assert autotune.cached_config(
            "flash_decode", serve_prob,
            cache_path=path) == {"block_kv": 128}
        assert autotune.cached_config(
            "flash_decode", serve_prob, cache_path=path,
            relax=("b", "cache_len")) == res.config
        # a different head layout never matches, relaxed or not
        other = autotune.flash_decode_problem(
            (2, 1, 8, 16), (2, 49, 4, 16), "float32")
        assert autotune.cached_config(
            "flash_decode", other, cache_path=path,
            relax=("b", "cache_len")) == {"block_kv": 128}


class TestKvPositions:
    def test_linear_cache(self):
        kv_pos = L.kv_positions_for_cache(jnp.asarray(5), 8, 0)
        assert kv_pos.tolist() == [0, 1, 2, 3, 4, 5, 2**30, 2**30]

    def test_ring_buffer_wrapped(self):
        # cache_len=4, pos=6 -> idx=2; slots hold [4, 5, 6, 3]
        kv_pos = L.kv_positions_for_cache(jnp.asarray(6), 4, 16)
        assert kv_pos.tolist() == [4, 5, 6, 3]

    def test_ring_buffer_unfilled(self):
        # pos=1 -> only slots 0..1 ever written
        kv_pos = L.kv_positions_for_cache(jnp.asarray(1), 4, 16)
        assert kv_pos.tolist() == [0, 1, 2**30, 2**30]


@pytest.mark.parametrize("arch,pos", [
    ("qwen2-7b", 0), ("qwen2-7b", 15),
    ("h2o-danube-3-4b", 0), ("h2o-danube-3-4b", 15),
    ("h2o-danube-3-4b", 45),                        # wrapped ring buffer
])
def test_attention_layer_kernel_matches_oracle(arch, pos):
    """layers.attention decode: ctx.use_kernels flash_decode path vs the
    jnp oracle — same output, same updated cache."""
    cfg = get_config(arch, smoke=True).replace(act_dtype="float32")
    cache_len = 16 if not cfg.sliding_window else min(16, cfg.sliding_window)
    if not cfg.sliding_window and pos >= cache_len:
        pytest.skip("linear cache: pos beyond cache")
    p, _ = L.init_attention(KEY, cfg)
    b = 2
    cache, _ = L.init_attention_cache(cfg, b, cache_len, dtype=jnp.float32)
    cache = dict(cache,
                 k=jax.random.normal(jax.random.PRNGKey(3),
                                     cache["k"].shape),
                 v=jax.random.normal(jax.random.PRNGKey(4),
                                     cache["v"].shape),
                 pos=jnp.asarray(pos, jnp.int32))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
    positions = jnp.asarray([pos])
    y_ref, c_ref = L.attention(Ctx(decode=True), cfg, p, x, positions,
                               dict(cache))
    y_ker, c_ker = L.attention(Ctx(decode=True, use_kernels=True,
                                   interpret=True), cfg, p, x, positions,
                               dict(cache))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    for leaf in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(c_ker[leaf]),
                                      np.asarray(c_ref[leaf]))


@pytest.mark.parametrize("use_kernels", [False, True])
def test_ring_prefill_overflow_then_decode(use_kernels):
    """Prompt longer than the window cache with s % cache_len != 0: the
    prefill must rotate the retained tail into ring layout so decode's
    position recovery reads the right slots (seed bug — the unrotated
    cache silently attended wrong keys)."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        act_dtype="float32")
    s, cache_len = 40, 32                    # window 32; 40 % 32 != 0
    assert cfg.sliding_window == cache_len
    p, _ = L.init_attention(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, s + 1, cfg.d_model)) * 0.3
    # reference: full-sequence sliding-window attention, last token
    y_full, _ = L.attention(Ctx(), cfg, p, x, jnp.arange(s + 1))
    cache, _ = L.init_attention_cache(cfg, 1, cache_len, dtype=jnp.float32)
    _, cache = L.attention(Ctx(), cfg, p, x[:, :s], jnp.arange(s), cache)
    ctx = Ctx(decode=True, use_kernels=use_kernels,
              interpret=use_kernels)
    y_dec, _ = L.attention(ctx, cfg, p, x[:, s:], jnp.asarray([s]), cache)
    np.testing.assert_allclose(np.asarray(y_dec),
                               np.asarray(y_full[:, -1:]),
                               rtol=2e-4, atol=2e-4)


class TestGenerateScanEquivalence:
    @pytest.mark.parametrize("arch", ["qwen2-7b", "h2o-danube-3-4b"])
    def test_scan_matches_python_loop(self, arch):
        """The fused lax.scan generation loop produces the same greedy
        tokens as the seed per-token Python loop."""
        from repro.launch.serve import generate, make_serve_fns
        from repro.models.api import build_model

        cfg = get_config(arch, smoke=True).replace(act_dtype="float32")
        model = build_model(cfg)
        params = model.init(KEY)
        prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8),
                                     0, cfg.vocab_size)
        fns = make_serve_fns(model)
        gen, cache_len = 6, 16
        t_loop = generate(model, params, prompts, gen, cache_len,
                          scan=False, fns=fns)
        t_scan = generate(model, params, prompts, gen, cache_len,
                          scan=True, fns=fns)
        assert t_loop.shape == t_scan.shape == (2, gen)
        np.testing.assert_array_equal(np.asarray(t_loop),
                                      np.asarray(t_scan))

    def test_kernel_scan_matches_jnp_loop(self):
        """End-to-end: flash_decode + scan vs the seed jnp Python loop."""
        from repro.launch.serve import generate
        from repro.models.api import build_model

        cfg = get_config("qwen2-7b", smoke=True).replace(
            act_dtype="float32")
        prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8),
                                     0, cfg.vocab_size)
        gen, cache_len = 6, 16
        m_jnp = build_model(cfg)
        m_ker = build_model(cfg, use_kernels=True, interpret=True)
        params = m_jnp.init(KEY)
        t_loop = generate(m_jnp, params, prompts, gen, cache_len,
                          scan=False)
        t_ker = generate(m_ker, params, prompts, gen, cache_len,
                         scan=True)
        np.testing.assert_array_equal(np.asarray(t_loop),
                                      np.asarray(t_ker))
