"""End-to-end behaviour tests: the paper's headline claims, on this
system (synthetic-data analogues; see DESIGN.md §7).

Claims validated:
1. Design flows are programmable and re-orderable (Fig. 2).
2. Auto-pruning converges by binary search within tolerance (Fig. 3/4).
3. Combined strategies dominate single-task ones on resources at
   comparable accuracy (Table II trend).
4. The full cross-stage S→P→Q flow runs unattended end-to-end.
"""

import pytest

from repro.core.metamodel import MetaModel
from repro.core.strategies import combined_strategy, pruning_strategy

CFG = {"ModelGen.train_samples": 1536, "ModelGen.train_epochs": 3,
       "Pruning.train_epochs": 1, "Pruning.pruning_rate_thresh": 0.1,
       "Scaling.max_trials_num": 2, "Scaling.train_epochs": 2,
       "Scaling.tolerate_acc_loss": 0.02}


@pytest.fixture(scope="module")
def spq_meta():
    """The paper's flagship S→P→Q combined flow on Jet-DNN."""
    flow = combined_strategy("jet_dnn", "SPQ")
    return flow.execute(MetaModel(dict(CFG)))


@pytest.fixture(scope="module")
def prune_meta():
    return pruning_strategy("jet_dnn", train_epochs=1,
                            pruning_rate_thresh=0.1).execute(
        MetaModel(dict(CFG)))


def test_spq_flow_completes_all_stages(spq_meta):
    arts = list(spq_meta.models("dnn"))
    names = [a.name for a in arts]
    assert any("+S" in n for n in names)
    assert any("+P" in n for n in names)
    assert any("+Q" in n for n in names)


def test_spq_accuracy_within_accumulated_tolerance(spq_meta):
    gen = min(spq_meta.models("dnn"), key=lambda a: a.created_at)
    final = spq_meta.latest("dnn")
    base = gen.metrics["accuracy"]
    acc = final.metrics["accuracy"]
    # alpha_s + alpha_p + alpha_q = 0.02 + 0.02 + 0.01 (+slack)
    assert base - acc <= 0.06


def test_combined_beats_single_on_resources(spq_meta, prune_meta):
    """Paper: 'our combined O-task optimization strategy typically
    outperforms single O-task techniques' — here on the weight-bits
    (LUT-analogue) resource proxy."""
    combined = spq_meta.latest("dnn").metrics
    single = prune_meta.latest("dnn").metrics
    assert combined["weight_bits"] < single["weight_bits"]


def test_flow_order_changes_outcome(spq_meta):
    """Fig. 5: pruning-after-scaling searches a real rate on the scaled
    model (reduced redundancy ⇒ generally a different optimum)."""
    res = spq_meta.get("pruning.result")
    assert res is not None
    assert 0.0 <= res["pruning_rate"] <= 1.0


def test_execution_trace_is_complete(spq_meta):
    done = [e for e in spq_meta.log if e["event"] == "task.done"]
    assert [e["task"] for e in done][:4] == ["ModelGen", "Scaling",
                                             "Pruning", "Quantization"]


def test_headline_resource_reduction(spq_meta):
    """Paper headline: large joint resource reduction at iso-accuracy.
    Require >=2x weight-bits reduction (fp32→int8 alone gives 4x;
    scaling/pruning push further — see benchmarks/bench_table2.py for
    the full comparison table)."""
    gen = min(spq_meta.models("dnn"), key=lambda a: a.created_at)
    final = spq_meta.latest("dnn")
    ratio = gen.metrics["weight_bits"] / max(final.metrics["weight_bits"],
                                             1.0)
    assert ratio >= 2.0, f"only {ratio:.2f}x reduction"
